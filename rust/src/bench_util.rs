//! Micro-benchmark harness (vendored-build replacement for criterion).
//!
//! Each `rust/benches/*.rs` target (built with `harness = false`) uses
//! [`Bench`] to time closures with warmup, report mean/min/max and
//! throughput, and emit machine-readable results two ways:
//!
//! * one `name,mean_ns,min_ns,max_ns,iters` CSV line per case on stdout
//!   ([`Bench::finish`], `cargo bench | tee bench_output.txt`);
//! * a JSON file ([`Bench::write_json`]) with every case's timing +
//!   throughput plus free-form [`Bench::note`] metrics — the
//!   `sim_throughput` bench writes `BENCH_sim.json` so CI tracks the
//!   engine's perf trajectory per commit.

use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::{obj, Json};

/// One benchmark suite (named group of timed cases).
pub struct Bench {
    suite: String,
    /// Target measurement time per case.
    pub measure_time: Duration,
    /// Warmup time per case.
    pub warmup_time: Duration,
    results: Vec<CaseResult>,
    notes: Vec<(String, f64)>,
    sections: Vec<(String, Json)>,
}

/// Timing result of one case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Case name.
    pub name: String,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// Fastest iteration.
    pub min_ns: f64,
    /// Slowest iteration.
    pub max_ns: f64,
    /// Iterations measured.
    pub iters: u64,
    /// Derived throughput, if [`Bench::throughput`] was called: units/s.
    pub throughput_per_sec: Option<f64>,
    /// Unit name of the derived throughput.
    pub throughput_unit: Option<String>,
}

impl Bench {
    /// New suite. Honors `ASYMM_SA_BENCH_FAST=1` (CI smoke mode: ~10× less
    /// measurement time).
    pub fn new(suite: &str) -> Self {
        let fast = std::env::var("ASYMM_SA_BENCH_FAST").is_ok();
        Bench {
            suite: suite.to_string(),
            measure_time: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            warmup_time: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(500)
            },
            results: Vec::new(),
            notes: Vec::new(),
            sections: Vec::new(),
        }
    }

    /// Time `f` until the measurement budget is spent (at least 5 iters).
    pub fn case<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &CaseResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup_time {
            black_box(f());
        }
        // Measure.
        let mut times = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure_time || times.len() < 5 {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
            if times.len() >= 1_000_000 {
                break;
            }
        }
        let n = times.len() as f64;
        let mean = times.iter().sum::<f64>() / n;
        let min = times.iter().cloned().fold(f64::MAX, f64::min);
        let max = times.iter().cloned().fold(0.0, f64::max);
        let res = CaseResult {
            name: name.to_string(),
            mean_ns: mean,
            min_ns: min,
            max_ns: max,
            iters: times.len() as u64,
            throughput_per_sec: None,
            throughput_unit: None,
        };
        println!(
            "{}/{:<40} mean {:>12}  min {:>12}  max {:>12}  ({} iters)",
            self.suite,
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.min_ns),
            fmt_ns(res.max_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().expect("just pushed")
    }

    /// Report a derived throughput metric for the last case (also
    /// recorded into the case for [`Bench::write_json`]).
    pub fn throughput(&mut self, units: f64, unit_name: &str) {
        if let Some(last) = self.results.last_mut() {
            let per_sec = units / (last.mean_ns * 1e-9);
            last.throughput_per_sec = Some(per_sec);
            last.throughput_unit = Some(unit_name.to_string());
            println!(
                "{}/{:<40} throughput {:.3e} {unit_name}/s",
                self.suite, last.name, per_sec
            );
        }
    }

    /// Record a named derived metric for the suite (e.g. a speedup ratio
    /// between two cases); lands in the JSON under `"metrics"`.
    pub fn note(&mut self, key: &str, value: f64) {
        println!("{}/{key} = {value:.3}", self.suite);
        self.notes.push((key.to_string(), value));
    }

    /// Attach a structured JSON payload to the suite (e.g. the sweep
    /// summary document `repro sweep` embeds in `SWEEP_summary.json`).
    /// Lands at the top level of [`Bench::to_json`] next to
    /// `suite`/`cases`/`metrics`; keys must not collide with those
    /// (colliding keys would be deduplicated by the object builder).
    pub fn section(&mut self, key: &str, value: Json) {
        self.sections.push((key.to_string(), value));
    }

    /// Print the machine-readable CSV trailer.
    pub fn finish(&self) {
        println!("---BENCH-CSV---");
        println!("suite,case,mean_ns,min_ns,max_ns,iters");
        for r in &self.results {
            println!(
                "{},{},{:.1},{:.1},{:.1},{}",
                self.suite, r.name, r.mean_ns, r.min_ns, r.max_ns, r.iters
            );
        }
    }

    /// Serialize the suite to JSON text (what [`Bench::write_json`] writes).
    pub fn to_json(&self) -> String {
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut pairs = vec![
                    ("suite", Json::Str(self.suite.clone())),
                    ("case", Json::Str(r.name.clone())),
                    ("mean_ns", Json::Num(r.mean_ns)),
                    ("min_ns", Json::Num(r.min_ns)),
                    ("max_ns", Json::Num(r.max_ns)),
                    ("iters", Json::Num(r.iters as f64)),
                ];
                if let (Some(t), Some(u)) = (r.throughput_per_sec, &r.throughput_unit) {
                    pairs.push(("throughput_per_sec", Json::Num(t)));
                    pairs.push(("throughput_unit", Json::Str(u.clone())));
                }
                obj(pairs)
            })
            .collect();
        let metrics = obj(self
            .notes
            .iter()
            .map(|(k, v)| (k.as_str(), Json::Num(*v)))
            .collect());
        let mut pairs = vec![
            ("suite", Json::Str(self.suite.clone())),
            ("cases", Json::Arr(cases)),
            ("metrics", metrics),
        ];
        for (k, v) in &self.sections {
            pairs.push((k.as_str(), v.clone()));
        }
        obj(pairs).to_string()
    }

    /// Write the suite results as a JSON file (`BENCH_sim.json` et al.),
    /// so the perf trajectory is machine-tracked per commit.
    pub fn write_json(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json())?;
        println!("{}: wrote {}", self.suite, path.display());
        Ok(())
    }

    /// Accumulated results (for programmatic assertions in tests).
    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_a_case() {
        std::env::set_var("ASYMM_SA_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        b.measure_time = Duration::from_millis(20);
        b.warmup_time = Duration::from_millis(2);
        let r = b.case("noop", || 1 + 1).clone();
        assert!(r.iters >= 5);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
        b.throughput(1.0, "ops");
        b.finish();
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].throughput_per_sec.unwrap() > 0.0);
    }

    #[test]
    fn json_roundtrips_cases_and_notes() {
        let mut b = Bench::new("jsontest");
        b.measure_time = Duration::from_millis(5);
        b.warmup_time = Duration::from_millis(1);
        b.case("one", || 1);
        b.throughput(10.0, "widget");
        b.note("speedup_x", 3.5);
        let parsed = crate::util::json::Json::parse(&b.to_json()).unwrap();
        assert_eq!(parsed.req("suite").unwrap().as_str().unwrap(), "jsontest");
        let cases = parsed.req("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].req("case").unwrap().as_str().unwrap(), "one");
        assert!(cases[0].req("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            cases[0].req("throughput_unit").unwrap().as_str().unwrap(),
            "widget"
        );
        let metrics = parsed.req("metrics").unwrap();
        assert!((metrics.req("speedup_x").unwrap().as_f64().unwrap() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn sections_land_in_json() {
        let mut b = Bench::new("sectiontest");
        b.note("n", 1.0);
        b.section(
            "sweep",
            obj(vec![("points", Json::Num(3.0)), ("ok", Json::Bool(true))]),
        );
        let parsed = crate::util::json::Json::parse(&b.to_json()).unwrap();
        let s = parsed.req("sweep").unwrap();
        assert_eq!(s.req("points").unwrap().as_usize().unwrap(), 3);
        assert!(s.req("ok").unwrap().as_bool().unwrap());
        // Standard keys still present.
        assert_eq!(parsed.req("suite").unwrap().as_str().unwrap(), "sectiontest");
        assert!(parsed.req("metrics").unwrap().get("n").is_some());
    }

    #[test]
    fn write_json_creates_file() {
        let mut b = Bench::new("filetest");
        b.measure_time = Duration::from_millis(5);
        b.warmup_time = Duration::from_millis(1);
        b.case("one", || 1);
        let path = std::env::temp_dir().join("asymm_sa_bench_selftest.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12e3).ends_with("µs"));
        assert!(fmt_ns(12e6).ends_with("ms"));
        assert!(fmt_ns(12e9).ends_with(" s"));
    }
}
