//! End-to-end experiment pipeline (the paper's §IV methodology):
//!
//! 1. generate per-layer inputs and He-init weights (ImageNet
//!    substitution, DESIGN.md §3);
//! 2. run the layer forward — through the AOT PJRT artifact when a
//!    [`Runtime`] is supplied (the production path: JAX/Pallas-compiled
//!    conv produces both activations and the quantized im2col patches),
//!    falling back to the native Rust im2col+quantize otherwise;
//! 3. simulate every GEMM on the WS array via the [`Coordinator`]
//!    (exact bus toggle statistics);
//! 4. pick the asymmetric aspect ratio from the measured average
//!    activities (eq. 6) unless pinned by the config;
//! 5. evaluate both floorplans with the power model → Fig. 4/5 rows.

use std::sync::Arc;


use crate::config::ExperimentConfig;
use crate::coordinator::{Coordinator, LayerJob, MetricsSnapshot};
use crate::error::Result;
use crate::floorplan::optimizer;
use crate::gemm::{im2col, Matrix};
use crate::quant::quantize_sym;
use crate::runtime::Runtime;
use crate::workloads::{ConvLayer, SynthGen};

use super::{average_row, power_row, LayerPowerRow};

/// Everything an experiment run produces.
#[derive(Debug, Clone)]
pub struct ExperimentOutput {
    /// Per-layer rows, in input order.
    pub rows: Vec<LayerPowerRow>,
    /// The per-layer average row (the paper's "Average" bar).
    pub average: LayerPowerRow,
    /// Aspect ratio actually used for the asymmetric design.
    pub aspect_used: f64,
    /// Average measured activities `(a_h, a_v)` across layers
    /// (paper §IV reports 0.22 / 0.36).
    pub avg_activities: (f64, f64),
    /// Coordinator metrics snapshot.
    pub metrics: MetricsSnapshot,
    /// Whether layer forwards ran through the PJRT artifacts.
    pub used_runtime: bool,
}

/// Build the quantized GEMM operands for one layer.
///
/// Returns `(a_q, w_q)`: int16 im2col patches `P×CK²` and weights
/// `CK²×M`, the exact words the array buses carry. Public because the
/// serve scenario generator ([`crate::serve::session`]) lowers its
/// request mix through the same path.
pub fn layer_operands(
    layer: &ConvLayer,
    gen: &mut SynthGen,
    runtime: Option<&Runtime>,
    act_model: &crate::workloads::ActivationModel,
) -> Result<(Matrix<i32>, Matrix<i32>)> {
    let (hin, win) = layer.input_hw();
    let x = gen.activations(layer.c, hin, win, act_model);
    let ck2 = layer.c * layer.k * layer.k;
    let w = gen.weights(layer.m, ck2);

    // Patches: through the AOT artifact when available (the L1/L2 path),
    // else the native Rust im2col + quantizer (bit-identical contract,
    // enforced by the runtime integration test).
    let a_q = match runtime {
        Some(rt) => {
            let (_out, q) = rt.layer_forward(&layer.name, &x, &w)?;
            q
        }
        None => {
            let patches = im2col(&x, layer.c, hin, win, layer.k, layer.stride, layer.pad())?;
            let q = quantize_sym(&patches.data, 16);
            Matrix::from_vec(patches.rows, patches.cols, q.values)?
        }
    };

    // Weights: quantized in Rust either way (the artifact consumes f32
    // weights for the forward; the array streams their int16 image).
    let wq = quantize_sym(&w, 16);
    let w_mat = Matrix::from_vec(layer.m, ck2, wq.values)?; // M×CK²
    Ok((a_q, w_mat.transpose()))
}

/// Lower `layers` into coordinator jobs: one seeded generator pass over
/// the whole list, operands via [`layer_operands`].
fn layer_jobs(
    cfg: &ExperimentConfig,
    layers: &[ConvLayer],
    runtime: Option<&Runtime>,
) -> Result<Vec<LayerJob>> {
    let mut gen = SynthGen::new(cfg.seed);
    let mut jobs = Vec::with_capacity(layers.len());
    for layer in layers {
        let (a_q, w_q) = layer_operands(layer, &mut gen, runtime, &cfg.activations)?;
        jobs.push(LayerJob {
            name: layer.name.clone(),
            a: Arc::new(a_q),
            w: Arc::new(w_q),
        });
    }
    Ok(jobs)
}

/// Run the full Table-I experiment and produce the Fig. 4/5 rows.
pub fn run_experiment(
    cfg: &ExperimentConfig,
    layers: &[ConvLayer],
    runtime: Option<&Runtime>,
) -> Result<ExperimentOutput> {
    let jobs = layer_jobs(cfg, layers, runtime)?;
    let coord = Coordinator::new(&cfg.sa, cfg.workers);
    let results = coord.run_blocking(jobs)?;

    // Average activities over layers → eq. 6 aspect (paper §III-B).
    let n = results.len() as f64;
    let a_h = results.iter().map(|r| r.sim.stats.horizontal.activity()).sum::<f64>() / n;
    let a_v = results.iter().map(|r| r.sim.stats.vertical.activity()).sum::<f64>() / n;
    let aspect_used = cfg
        .floorplans
        .proposed_aspect
        .unwrap_or_else(|| optimizer::closed_form_ratio(&cfg.sa, a_h, a_v));

    let sym = cfg.baseline_geometry()?;
    let asym = crate::floorplan::PeGeometry::new(cfg.pe_area_um2(), aspect_used)?;

    let rows: Vec<LayerPowerRow> = results
        .iter()
        .map(|r| power_row(&r.name, &cfg.sa, &cfg.tech, &sym, &asym, &r.sim))
        .collect();
    let average = average_row(&rows).expect("non-empty experiment");

    Ok(ExperimentOutput {
        rows,
        average,
        aspect_used,
        avg_activities: (a_h, a_v),
        metrics: coord.metrics().snapshot(),
        used_runtime: runtime.is_some(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet50::ConvLayer as CL;

    fn tiny_layers() -> Vec<CL> {
        vec![
            CL {
                name: "T1".into(),
                k: 1,
                h: 8,
                w: 8,
                c: 16,
                m: 16,
                stride: 1,
            },
            CL {
                name: "T2".into(),
                k: 3,
                h: 6,
                w: 6,
                c: 8,
                m: 8,
                stride: 1,
            },
        ]
    }

    #[test]
    fn experiment_runs_without_runtime() {
        let mut cfg = ExperimentConfig::paper();
        cfg.sa = crate::arch::SaConfig::new_ws(8, 8, 16).unwrap();
        cfg.workers = 2;
        let out = run_experiment(&cfg, &tiny_layers(), None).unwrap();
        assert_eq!(out.rows.len(), 2);
        assert!(!out.used_runtime);
        assert_eq!(out.metrics.jobs, 2);
        // Headline shape: asym saves interconnect power on every layer.
        for r in &out.rows {
            assert!(r.interconnect_reduction() > 0.0, "{}", r.name);
        }
        assert!(out.average.interconnect_reduction() > 0.0);
        // Activity asymmetry present (a_v > a_h).
        let (ah, av) = out.avg_activities;
        assert!(av > ah, "a_v={av} a_h={ah}");
    }

    #[test]
    fn derived_aspect_uses_measured_activities() {
        let mut cfg = ExperimentConfig::paper();
        cfg.sa = crate::arch::SaConfig::new_ws(8, 8, 16).unwrap();
        cfg.floorplans.proposed_aspect = None;
        cfg.workers = 1;
        let out = run_experiment(&cfg, &tiny_layers(), None).unwrap();
        let (ah, av) = out.avg_activities;
        let want = optimizer::closed_form_ratio(&cfg.sa, ah, av);
        assert!((out.aspect_used - want).abs() < 1e-12);
        assert!(out.aspect_used > 1.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut cfg = ExperimentConfig::paper();
        cfg.sa = crate::arch::SaConfig::new_ws(8, 8, 16).unwrap();
        let a = run_experiment(&cfg, &tiny_layers(), None).unwrap();
        let b = run_experiment(&cfg, &tiny_layers(), None).unwrap();
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.aspect_used, b.aspect_used);
    }
}
