//! Figure/table regeneration: the paper's Table I, Fig. 4 and Fig. 5.
//!
//! Consumes per-layer simulation results + two floorplans and produces
//! the rows the paper plots: interconnect power (Fig. 4) and total power
//! (Fig. 5) for symmetric vs asymmetric layouts, per layer and averaged.

pub mod pipeline;

pub use pipeline::{run_experiment, ExperimentOutput};

use std::fmt::Write as _;


use crate::arch::SaConfig;
use crate::floorplan::PeGeometry;
use crate::power::{self, PowerBreakdown, TechParams};
use crate::sim::GemmSim;
use crate::workloads::ConvLayer;

/// One row of the Fig. 4/5 comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPowerRow {
    /// Layer name (Table-I "L1".."L6" or "avg").
    pub name: String,
    /// Measured horizontal switching activity.
    pub a_h: f64,
    /// Measured vertical switching activity.
    pub a_v: f64,
    /// Zero fraction on the horizontal bus (input sparsity signature).
    pub zero_h: f64,
    /// Power on the symmetric (square-PE) floorplan.
    pub sym: PowerBreakdown,
    /// Power on the asymmetric floorplan.
    pub asym: PowerBreakdown,
}

impl LayerPowerRow {
    /// Fractional interconnect power reduction (Fig. 4 headline: 9.1%).
    pub fn interconnect_reduction(&self) -> f64 {
        1.0 - self.asym.interconnect_mw() / self.sym.interconnect_mw()
    }

    /// Fractional total power reduction (Fig. 5 headline: 2.1%).
    pub fn total_reduction(&self) -> f64 {
        1.0 - self.asym.total_mw() / self.sym.total_mw()
    }
}

/// Evaluate one simulated layer on both floorplans.
pub fn power_row(
    name: &str,
    sa: &SaConfig,
    tech: &TechParams,
    sym: &PeGeometry,
    asym: &PeGeometry,
    sim: &GemmSim,
) -> LayerPowerRow {
    let (a_h, a_v) = sim.stats.activities();
    LayerPowerRow {
        name: name.to_string(),
        a_h,
        a_v,
        zero_h: sim.stats.horizontal.zero_fraction(),
        sym: power::evaluate(sa, sym, tech, sim),
        asym: power::evaluate(sa, asym, tech, sim),
    }
}

/// Arithmetic per-layer average row (how the paper's "Average" bar is
/// built: mean of the per-layer power draws).
pub fn average_row(rows: &[LayerPowerRow]) -> Option<LayerPowerRow> {
    if rows.is_empty() {
        return None;
    }
    let n = rows.len() as f64;
    let avg_pb = |f: &dyn Fn(&LayerPowerRow) -> PowerBreakdown| {
        let mut acc = PowerBreakdown::default();
        for r in rows {
            let p = f(r);
            acc.h_bus_mw += p.h_bus_mw;
            acc.v_bus_mw += p.v_bus_mw;
            acc.w_load_mw += p.w_load_mw;
            acc.ctrl_mw += p.ctrl_mw;
            acc.mac_mw += p.mac_mw;
            acc.reg_mw += p.reg_mw;
            acc.leak_mw += p.leak_mw;
        }
        acc.h_bus_mw /= n;
        acc.v_bus_mw /= n;
        acc.w_load_mw /= n;
        acc.ctrl_mw /= n;
        acc.mac_mw /= n;
        acc.reg_mw /= n;
        acc.leak_mw /= n;
        acc
    };
    Some(LayerPowerRow {
        name: "avg".to_string(),
        a_h: rows.iter().map(|r| r.a_h).sum::<f64>() / n,
        a_v: rows.iter().map(|r| r.a_v).sum::<f64>() / n,
        zero_h: rows.iter().map(|r| r.zero_h).sum::<f64>() / n,
        sym: avg_pb(&|r| r.sym),
        asym: avg_pb(&|r| r.asym),
    })
}

/// Pretty-print the paper's Table I.
pub fn table1_string(layers: &[ConvLayer]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Table I — selected ResNet50 layers");
    let _ = writeln!(s, "{:<6} {:>3} {:>5} {:>5} {:>6} {:>6}  GEMM (P x CK2 x M)", "Name", "K", "H", "W", "C", "M");
    for l in layers {
        let (p, ck2, m) = crate::workloads::gemm_shape(l);
        let _ = writeln!(
            s,
            "{:<6} {:>3} {:>5} {:>5} {:>6} {:>6}  {p} x {ck2} x {m}",
            l.name, l.k, l.h, l.w, l.c, l.m
        );
    }
    s
}

/// Render the Fig. 4 data series (interconnect power, sym vs asym).
pub fn fig4_string(rows: &[LayerPowerRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Fig. 4 — interconnect power (mW), symmetric vs asymmetric");
    let _ = writeln!(
        s,
        "{:<6} {:>10} {:>10} {:>9}  {:>7} {:>7}",
        "Layer", "sym", "asym", "saving", "a_h", "a_v"
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{:<6} {:>10.3} {:>10.3} {:>8.1}%  {:>7.3} {:>7.3}",
            r.name,
            r.sym.interconnect_mw(),
            r.asym.interconnect_mw(),
            100.0 * r.interconnect_reduction(),
            r.a_h,
            r.a_v,
        );
    }
    s
}

/// Render the Fig. 5 data series (total power, sym vs asym).
pub fn fig5_string(rows: &[LayerPowerRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Fig. 5 — total power (mW), symmetric vs asymmetric");
    let _ = writeln!(
        s,
        "{:<6} {:>10} {:>10} {:>9}  {:>8}",
        "Layer", "sym", "asym", "saving", "ic share"
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{:<6} {:>10.3} {:>10.3} {:>8.2}%  {:>7.1}%",
            r.name,
            r.sym.total_mw(),
            r.asym.total_mw(),
            100.0 * r.total_reduction(),
            100.0 * r.sym.interconnect_share(),
        );
    }
    s
}

/// Full markdown experiment report: Table I, measured activities,
/// Fig. 4/5 series, timing check — everything `repro report` writes.
pub fn markdown_report(
    cfg: &crate::config::ExperimentConfig,
    layers: &[ConvLayer],
    out: &pipeline::ExperimentOutput,
) -> String {
    use crate::floorplan::{PeGeometry, WireTiming};
    let mut s = String::new();
    let _ = writeln!(s, "# asymm-sa experiment report\n");
    let _ = writeln!(
        s,
        "Array: {}x{} WS, B_h={}, B_v={}, {} GHz; PE area {:.0} um^2; seed {}.\n",
        cfg.sa.rows,
        cfg.sa.cols,
        cfg.sa.bus_bits_horizontal(),
        cfg.sa.bus_bits_vertical(),
        cfg.sa.clock_ghz,
        cfg.pe_area_um2(),
        cfg.seed,
    );
    let _ = writeln!(s, "```\n{}```\n", table1_string(layers));
    let _ = writeln!(
        s,
        "Measured average activities: a_h = {:.3}, a_v = {:.3} (paper: 0.22 / 0.36).",
        out.avg_activities.0, out.avg_activities.1
    );
    let _ = writeln!(
        s,
        "Asymmetric aspect ratio W/H = {:.3} (paper: 3.8; eq. 6).\n",
        out.aspect_used
    );
    let mut rows = out.rows.clone();
    rows.push(out.average.clone());
    let _ = writeln!(s, "```\n{}```\n", fig4_string(&rows));
    let _ = writeln!(s, "```\n{}```\n", fig5_string(&rows));
    let _ = writeln!(
        s,
        "Headline: interconnect saving {:.1}% (paper 9.1%), total saving {:.2}% (paper 2.1%).\n",
        100.0 * out.average.interconnect_reduction(),
        100.0 * out.average.total_reduction()
    );
    // Zero-performance-cost check.
    let timing = WireTiming::default();
    let _ = writeln!(s, "Timing (Elmore, 28nm defaults):\n");
    for (label, aspect) in [("square", 1.0), ("asymmetric", out.aspect_used)] {
        if let Ok(pe) = PeGeometry::new(cfg.pe_area_um2(), aspect) {
            let _ = writeln!(
                s,
                "* {label} (W/H={aspect:.2}): max bus clock {:.1} GHz — {}",
                timing.max_clock_ghz(&pe),
                if timing.meets_timing(&cfg.sa, &pe) {
                    "meets target"
                } else {
                    "FAILS target"
                }
            );
        }
    }
    let _ = writeln!(
        s,
        "\nPipeline: {} jobs, {:.1}M MACs, {:.2}e9 simulated PE-cycles/s, PJRT runtime: {}.",
        out.metrics.jobs,
        out.metrics.macs as f64 / 1e6,
        out.metrics.pe_cycles_per_sec(cfg.sa.num_pes()) / 1e9,
        out.used_runtime
    );
    // Stable sorted-view percentiles (deterministic at any worker count).
    let _ = writeln!(
        s,
        "Per-job sim wall time: p50 {:.2} ms, p99 {:.2} ms over {} jobs.",
        out.metrics.job_wall_percentile_ms(0.50),
        out.metrics.job_wall_percentile_ms(0.99),
        out.metrics.job_wall_sorted_micros.len(),
    );
    s
}

/// Markdown design-space sweep report: per-workload square baseline,
/// Pareto-frontier table and headline numbers — what `repro sweep`
/// writes next to `SWEEP_summary.json`.
pub fn sweep_markdown(
    cfg: &crate::explore::SweepConfig,
    out: &crate::explore::SweepOutput,
) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# asymm-sa design-space sweep\n");
    let _ = writeln!(
        s,
        "PE budget {}: {} geometries x {} dataflows x {} workloads; aspect grid \
         [{}, {}] x {} points; seed {}.\n",
        cfg.pe_budget,
        crate::explore::factorizations(cfg.pe_budget).len(),
        cfg.dataflows.len(),
        cfg.workloads.len(),
        cfg.aspect_lo,
        cfg.aspect_hi,
        cfg.aspect_points,
        cfg.seed,
    );
    for (wi, _) in cfg.workloads.iter().enumerate() {
        let h = out.headline(cfg, wi);
        let base = &out.baselines[wi];
        let _ = writeln!(s, "## Workload `{}`\n", h.workload.name());
        let _ = writeln!(
            s,
            "Square {}x{} WS baseline: {:.3} mW interconnect, {:.3} mW total, {} cycles.\n",
            base.rows,
            base.cols,
            base.square.interconnect_mw,
            base.square.total_mw,
            base.cycles,
        );
        let _ = writeln!(
            s,
            "| geometry | dataflow | best W/H | cycles | interconnect (mW) | vs square | eq.6 W/H | eq.5 W/H |"
        );
        let _ = writeln!(s, "|---|---|---|---|---|---|---|---|");
        for &i in &out.pareto[wi] {
            let p = &out.points[i];
            let _ = writeln!(
                s,
                "| {}x{} | {} | {:.2} | {} | {:.3} | {:+.1}% | {:.2} | {:.2} |",
                p.rows,
                p.cols,
                p.dataflow.name(),
                p.best.aspect,
                p.cycles,
                p.best.interconnect_mw,
                100.0 * (p.best.interconnect_mw / base.square.interconnect_mw - 1.0),
                p.eq6_ratio,
                p.eq5_ratio,
            );
        }
        let _ = writeln!(
            s,
            "\nBest point `{}` at W/H = {:.2}: {:.3} mW interconnect, {:.1}% below the \
             square baseline ({}).",
            h.best_label,
            h.best_aspect,
            h.best_interconnect_mw,
            100.0 * h.interconnect_saving,
            if h.best_beats_square {
                "beats square"
            } else {
                "does NOT beat square"
            },
        );
        let _ = writeln!(
            s,
            "Eq.-6 closed form W/H = {:.3} vs swept bus-power optimum: {}.\n",
            h.eq6_ratio,
            if h.eq6_within_one_step {
                "within one grid step"
            } else {
                "OUTSIDE one grid step"
            },
        );
    }
    let _ = writeln!(
        s,
        "Cache traffic this run: {} hits / {} lookups, {} cold simulations.",
        out.cache.hits,
        out.cache.hits + out.cache.misses,
        out.cache.misses,
    );
    s
}

/// Markdown fleet-serving report: the provisioning decision, one row
/// per `(fleet, policy)` run (power, modeled latency, spills, cache
/// traffic) and the headline heterogeneous-vs-square margin — what
/// `repro fleet` writes next to `FLEET_summary.json`. Deterministic:
/// every number comes from the worker-count-invariant report.
pub fn fleet_markdown(
    cfg: &crate::fleet::FleetConfig,
    report: &crate::fleet::FleetReport,
) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# asymm-sa fleet serving\n");
    let _ = writeln!(
        s,
        "{} arrays x {} PEs each (equal total PE count per fleet), workload \
         `{}`, {} requests, seed {}; modeled arrival gap {:.1} us, spill bound \
         {} MACs.\n",
        cfg.arrays,
        cfg.pe_budget,
        report.plan.workload.name(),
        report.requests,
        cfg.seed,
        report.gap_us,
        report.spill_macs,
    );
    let _ = writeln!(s, "## Provisioning\n");
    let _ = writeln!(s, "Pareto frontier (cycle order):\n");
    for f in &report.plan.frontier {
        let _ = writeln!(s, "* {f}");
    }
    let _ = writeln!(
        s,
        "\n| fleet | arrays (energy rank) |\n|---|---|\n| heterogeneous | {} |\n| square | {} x {} |\n",
        report
            .plan
            .selected
            .iter()
            .map(|a| a.label())
            .collect::<Vec<_>>()
            .join(", "),
        report.plan.square.len(),
        report.plan.square[0].label(),
    );
    let _ = writeln!(s, "## Policy comparison\n");
    let _ = writeln!(
        s,
        "| fleet | policy | interconnect (uJ) | avg interconnect (mW) | p50 (us) | p99 (us) | spills | cache hits |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|");
    for r in &report.runs {
        let hits: u64 = r.per_array.iter().map(|a| a.cache.hits).sum();
        let lookups: u64 = r
            .per_array
            .iter()
            .map(|a| a.cache.hits + a.cache.misses)
            .sum();
        let _ = writeln!(
            s,
            "| {} | {} | {:.2} | {:.2} | {} | {} | {} | {}/{} |",
            r.fleet,
            r.policy.name(),
            r.interconnect_uj,
            r.avg_interconnect_mw(),
            r.latency_us(0.50),
            r.latency_us(0.99),
            r.spills,
            hits,
            lookups,
        );
    }
    let h = report.headline();
    let _ = writeln!(
        s,
        "\nHeadline: the `shape_affine`-routed heterogeneous fleet spends \
         {:.2} uJ of interconnect energy vs {:.2} uJ for the homogeneous \
         square fleet — a {:.1}% margin ({:.1}% on time-averaged interconnect \
         power), with `shape_affine` {:.1}% ahead of `round_robin` on its own \
         fleet. Modeled p99: {} us (heterogeneous) vs {} us (best square \
         policy).",
        h.het_interconnect_uj,
        h.square_interconnect_uj,
        100.0 * h.interconnect_margin,
        100.0 * h.power_margin,
        100.0 * h.affine_vs_round_robin,
        h.het_p99_us,
        h.square_p99_us,
    );
    let _ = writeln!(
        s,
        "\nPer-array utilization ({}): {}",
        "shape_affine",
        report
            .run(crate::fleet::HETEROGENEOUS, crate::fleet::RoutePolicy::ShapeAffine)
            .map(|r| {
                r.per_array
                    .iter()
                    .map(|a| format!("{} {:.1}% ({} req)", a.label, 100.0 * a.utilization, a.requests))
                    .collect::<Vec<_>>()
                    .join(", ")
            })
            .unwrap_or_default(),
    );
    s
}

/// Markdown chaos/degradation report: the injected schedule and the
/// degradation rollup of every seeded scenario against the fault-free
/// baseline — what `repro chaos` writes next to `CHAOS_summary.json`.
/// Deterministic: every number comes from the worker-count-invariant
/// report.
pub fn chaos_markdown(
    ccfg: &crate::faults::ChaosConfig,
    report: &crate::faults::ChaosReport,
) -> String {
    let base = report
        .baseline
        .run(crate::fleet::HETEROGENEOUS, crate::fleet::RoutePolicy::ShapeAffine)
        .expect("baseline always carries the headline lane");
    let mut s = String::new();
    let _ = writeln!(s, "# asymm-sa fault tolerance\n");
    let _ = writeln!(
        s,
        "{} seeded fault scenario(s) over the fleet comparison: {} arrays x \
         {} PEs each, workload `{}`, {} requests, seed {}. Retry limit {}, \
         queue bound {}, hot spare {}.\n",
        ccfg.scenarios,
        ccfg.fleet.arrays,
        ccfg.fleet.pe_budget,
        ccfg.fleet.workload.name(),
        report.requests,
        ccfg.fleet.seed,
        ccfg.knobs.retry_limit,
        if ccfg.knobs.queue_bound == 0 {
            "unbounded".to_string()
        } else {
            ccfg.knobs.queue_bound.to_string()
        },
        match &report.spare {
            Some(sp) => format!("`{}`", sp.label()),
            None => "off".to_string(),
        },
    );
    let _ = writeln!(
        s,
        "Fault-free baseline (heterogeneous fleet, `shape_affine` routing): \
         p50 {} us, p99 {} us, p99.9 {} us, {:.2} uJ interconnect energy.\n",
        base.latency_us(0.50),
        base.latency_us(0.99),
        base.latency_us(0.999),
        base.interconnect_uj,
    );
    let _ = writeln!(s, "## Injected schedules\n");
    for sc in &report.scenarios {
        let _ = writeln!(
            s,
            "* scenario {}: {}",
            sc.scenario,
            sc.plan
                .events
                .iter()
                .map(|e| e.label())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    let _ = writeln!(s, "\n## Degradation vs fault-free\n");
    let _ = writeln!(
        s,
        "| scenario | completion | p50 | p99 | p99.9 | retries | failovers | \
         lost | promotions | recovery (uJ) | energy overhead |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|---|---|");
    for sc in &report.scenarios {
        let d = report.degradation(sc);
        let _ = writeln!(
            s,
            "| {} | {:.1}% | x{:.2} | x{:.2} | x{:.2} | {} | {} | {} | {} | \
             {:.2} | {:+.1}% |",
            d.scenario,
            100.0 * d.completion_rate,
            d.p50_inflation,
            d.p99_inflation,
            d.p999_inflation,
            d.retries,
            d.failovers,
            d.lost,
            d.promotions,
            d.recovery_uj,
            d.energy_overhead_pct,
        );
    }
    let h = report.headline();
    let _ = writeln!(
        s,
        "\nHeadline: across {} scenario(s) the `shape_affine`-routed \
         heterogeneous fleet completes {:.1}% of the trace on average \
         (worst case {:.1}%), with worst-case p99 inflation x{:.2}; \
         {} retries, {} failovers and {} hot-spare promotion(s) cost \
         {:.2} uJ of modeled recovery energy, and {} request(s) were lost.",
        h.scenarios,
        100.0 * h.mean_completion_rate,
        100.0 * h.min_completion_rate,
        h.worst_p99_inflation,
        h.total_retries,
        h.total_failovers,
        h.total_promotions,
        h.total_recovery_uj,
        h.total_lost,
    );
    s
}

/// Render the drift-adaptation comparison (`repro drift`) as markdown:
/// scenario echo, the two lanes side by side, and the post-cutover
/// margin headline.
pub fn drift_markdown(
    dcfg: &crate::fleet::DriftConfig,
    report: &crate::fleet::DriftReport,
) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# asymm-sa drift adaptation\n");
    let _ = writeln!(
        s,
        "{} requests under `{}` arrivals on {} x {}-PE arrays, workload \
         `{}`, seed {}. The layer mix shifts at request {} (phase split \
         {:.2}); the detector watches a {}-request window and adapts at \
         divergence >= {:.2}. Modeled gap {:.1} us, spill bound {} MACs.\n",
        report.requests,
        report.arrival.name(),
        dcfg.fleet.arrays,
        dcfg.fleet.pe_budget,
        dcfg.fleet.workload.name(),
        dcfg.fleet.seed,
        report.phase_at,
        dcfg.phase_split,
        dcfg.detect_window,
        dcfg.divergence_threshold,
        report.gap_us,
        report.spill_macs,
    );
    let _ = writeln!(s, "## Provisioning\n");
    for spec in &report.plan.selected {
        let _ = writeln!(s, "* `{}`", spec.label());
    }
    let _ = writeln!(s, "\n## Adaptive vs static\n");
    let _ = writeln!(
        s,
        "| lane | adapted | cutover | peak divergence | p99 (us) | \
         p99.9 (us) | interconnect (uJ) | pre (uJ) | post (uJ) | \
         post p99 (us) | warmup (uJ) |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|---|---|");
    for lane in [&report.adaptive, &report.static_run] {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {:.3} | {} | {} | {:.2} | {:.2} | {:.2} | {} | {:.2} |",
            lane.run.fleet,
            if lane.adapted { "yes" } else { "no" },
            lane.cutover_index
                .map(|i| i.to_string())
                .unwrap_or_else(|| "-".to_string()),
            lane.peak_divergence,
            lane.run.latency_us(0.99),
            lane.run.latency_us(0.999),
            lane.run.interconnect_uj,
            lane.pre_interconnect_uj,
            lane.post_interconnect_uj,
            lane.post_latency_us(0.99),
            lane.warmup_uj,
        );
    }
    if report.adaptive.adapted {
        let _ = writeln!(s, "\n## Re-provisioned arrays\n");
        for spec in &report.adaptive.specs_after {
            let _ = writeln!(s, "* `{}`", spec.label());
        }
    }
    let h = report.headline();
    if h.adapted {
        let _ = writeln!(
            s,
            "\nHeadline: the fleet detected the mix shift and cut over at \
             request {}; post-cutover it spends {:.2} uJ of interconnect \
             energy vs {:.2} uJ static — a {:+.1}% margin — at p99 {} us \
             vs {} us (p99.9 {} vs {} us), for {:.2} uJ of one-time cache \
             warmup.",
            h.cutover_index.expect("adapted lane has a cutover"),
            h.adaptive_post_uj,
            h.static_post_uj,
            h.post_margin_pct,
            h.adaptive_p99_us,
            h.static_p99_us,
            h.adaptive_p999_us,
            h.static_p999_us,
            h.warmup_uj,
        );
    } else {
        let _ = writeln!(
            s,
            "\nHeadline: no adaptation triggered (peak divergence {:.3}, \
             threshold {:.2}, detect window {}); both lanes served the \
             trace on the provisioned fleet.",
            report.adaptive.peak_divergence,
            dcfg.divergence_threshold,
            dcfg.detect_window,
        );
    }
    s
}

/// Markdown critical-path digest of a recorded trace: per track and
/// per priority class, attribute the modeled p99 latency to queue wait
/// vs engine service vs retries, then roll up per-array service time.
/// Deterministic: spans carry only modeled time, so the digest is
/// byte-identical at any worker count.
pub fn trace_markdown(tracer: &crate::obs::Tracer) -> String {
    use crate::obs::{RejectCause, SpanKind};
    use std::collections::BTreeMap;

    // Reassemble each request's critical path from its spans. Keyed by
    // (track, request): one request appears on exactly one track.
    #[derive(Default, Clone)]
    struct ReqPath {
        class: u8,
        queue_us: u64,
        engine_us: u64,
        retries: u64,
        billed: bool,
    }
    let mut paths: BTreeMap<(usize, u64), ReqPath> = BTreeMap::new();
    for s in tracer.spans() {
        let Some(rid) = s.request else { continue };
        let p = paths.entry((s.track, rid)).or_default();
        if let Some(c) = s.class {
            p.class = c;
        }
        let dur = s.end_us - s.begin_us;
        match s.kind {
            SpanKind::QueueWait => p.queue_us += dur,
            SpanKind::Engine => p.engine_us += dur,
            SpanKind::Retry => p.retries += 1,
            SpanKind::Bill => p.billed = true,
            _ => {}
        }
    }

    let mut s = String::new();
    let _ = writeln!(s, "# asymm-sa trace digest\n");
    let _ = writeln!(
        s,
        "{} span(s), {} rejection event(s) over {} track(s); all times \
         are modeled µs (no wall clock in this digest or the trace it \
         summarizes).\n",
        tracer.spans().len(),
        tracer.rejects().len(),
        tracer.tracks().len(),
    );

    // Nearest-rank percentile over a sorted slice (matches the repo's
    // latency convention).
    let pct = |sorted: &[u64], p: f64| -> u64 {
        if sorted.is_empty() {
            return 0;
        }
        let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    };

    let _ = writeln!(s, "## Critical path by class\n");
    let _ = writeln!(
        s,
        "| track | class | requests | billed | p99 total (us) | \
         p99 queue (us) | p99 engine (us) | queue share | retries |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|");
    // Group by (track, class).
    let mut groups: BTreeMap<(usize, u8), Vec<&ReqPath>> = BTreeMap::new();
    for ((track, _), p) in &paths {
        groups.entry((*track, p.class)).or_default().push(p);
    }
    for ((track, class), reqs) in &groups {
        let mut totals: Vec<u64> = reqs.iter().map(|p| p.queue_us + p.engine_us).collect();
        let mut queues: Vec<u64> = reqs.iter().map(|p| p.queue_us).collect();
        let mut engines: Vec<u64> = reqs.iter().map(|p| p.engine_us).collect();
        totals.sort_unstable();
        queues.sort_unstable();
        engines.sort_unstable();
        let billed = reqs.iter().filter(|p| p.billed).count();
        let retries: u64 = reqs.iter().map(|p| p.retries).sum();
        let queue_sum: u64 = queues.iter().sum();
        let total_sum: u64 = totals.iter().sum::<u64>().max(1);
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {} | {} | {} | {:.1}% | {} |",
            tracer
                .tracks()
                .get(*track)
                .map(|t| t.as_str())
                .unwrap_or("?"),
            class,
            reqs.len(),
            billed,
            pct(&totals, 0.99),
            pct(&queues, 0.99),
            pct(&engines, 0.99),
            100.0 * queue_sum as f64 / total_sum as f64,
            retries,
        );
    }

    let _ = writeln!(s, "\n## Service time by array\n");
    let _ = writeln!(s, "| track | array | engine spans | busy (us) |");
    let _ = writeln!(s, "|---|---|---|---|");
    let mut arrays: BTreeMap<(usize, usize), (u64, u64)> = BTreeMap::new();
    for sp in tracer.spans() {
        if sp.kind == SpanKind::Engine {
            if let Some(a) = sp.array {
                let e = arrays.entry((sp.track, a)).or_default();
                e.0 += 1;
                e.1 += sp.end_us - sp.begin_us;
            }
        }
    }
    for ((track, array), (n, busy)) in &arrays {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} |",
            tracer
                .tracks()
                .get(*track)
                .map(|t| t.as_str())
                .unwrap_or("?"),
            array,
            n,
            busy,
        );
    }

    let _ = writeln!(s, "\n## Rejections\n");
    let _ = writeln!(s, "| cause | events |");
    let _ = writeln!(s, "|---|---|");
    for cause in RejectCause::ALL {
        let _ = writeln!(s, "| {} | {} |", cause.name(), tracer.reject_count(cause));
    }
    let bills = tracer.count(SpanKind::Bill);
    let rejects = tracer.rejects().len();
    let _ = writeln!(
        s,
        "\nAccounting: {} terminal `bill` span(s) + {} rejection event(s) \
         cover every admission decision exactly once (pinned by \
         `tests/trace_determinism.rs`).",
        bills, rejects,
    );
    s
}

/// CSV export of the full comparison (one row per layer).
pub fn to_csv(rows: &[LayerPowerRow]) -> String {
    let mut s = String::from(
        "layer,a_h,a_v,zero_h,sym_interconnect_mw,asym_interconnect_mw,\
         sym_total_mw,asym_total_mw,interconnect_reduction,total_reduction\n",
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6}",
            r.name,
            r.a_h,
            r.a_v,
            r.zero_h,
            r.sym.interconnect_mw(),
            r.asym.interconnect_mw(),
            r.sym.total_mw(),
            r.asym.total_mw(),
            r.interconnect_reduction(),
            r.total_reduction(),
        );
    }
    s
}

/// Markdown report of a daemon run, rendered from the daemon's own
/// `DAEMON_summary.json` document — one source of truth, so the report
/// cannot drift from what the JSON artifact records.
pub fn daemon_markdown(
    cfg: &crate::daemon::DaemonConfig,
    summary: &crate::util::json::Json,
) -> String {
    use crate::util::json::Json;
    let num = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    let text = |j: &Json, k: &str| {
        j.get(k)
            .and_then(|v| v.as_str().ok())
            .unwrap_or("?")
            .to_string()
    };
    let fcfg = &cfg.fleet;
    let rejected = summary.get("rejected").cloned().unwrap_or(Json::Null);
    let scfg = summary.get("config").cloned().unwrap_or(Json::Null);
    let mut s = String::new();
    let _ = writeln!(s, "# asymm-sa serving daemon\n");
    let _ = writeln!(
        s,
        "{} x {}-PE arrays, workload `{}`, {} priority class(es), \
         admission window {}, seed {}. Queue bound {} per array \
         (per-class watermarks), default deadline {} us (0 = none), \
         re-provision every {} admissions (0 = off). All latency and \
         every counter below are modeled — a pure function of the \
         request script, identical at any worker count.\n",
        fcfg.arrays,
        fcfg.pe_budget,
        fcfg.workload.name(),
        fcfg.classes.max(1),
        fcfg.window,
        fcfg.seed,
        num(&scfg, "queue_bound"),
        cfg.deadline_us,
        cfg.reprovision_every,
    );
    let _ = writeln!(s, "## Accounting\n");
    let _ = writeln!(
        s,
        "| state | accepted | completed | billed | shed (queue) | \
         shed (deadline) | shed (draining) | reprovisions | \
         drain latency (us) | modeled clock (us) |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|---|");
    let _ = writeln!(
        s,
        "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |",
        text(summary, "state"),
        num(summary, "accepted"),
        num(summary, "completed"),
        num(summary, "billed"),
        num(&rejected, "queue_full"),
        num(&rejected, "deadline_exceeded"),
        num(&rejected, "draining"),
        num(summary, "reprovisions"),
        num(summary, "drain_latency_us"),
        num(summary, "clock_us"),
    );
    let _ = writeln!(s, "\n## Modeled latency\n");
    let _ = writeln!(s, "| class | requests | p50 (us) | p99 (us) | p99.9 (us) |");
    let _ = writeln!(s, "|---|---|---|---|---|");
    let _ = writeln!(
        s,
        "| all | {} | {} | {} | {} |",
        num(summary, "accepted"),
        num(summary, "p50_us"),
        num(summary, "p99_us"),
        num(summary, "p999_us"),
    );
    if let Some(Json::Arr(classes)) = summary.get("per_class") {
        for c in classes {
            let _ = writeln!(
                s,
                "| {} | {} | {} | {} | {} |",
                num(c, "class"),
                num(c, "requests"),
                num(c, "p50_us"),
                num(c, "p99_us"),
                num(c, "p999_us"),
            );
        }
    }
    let _ = writeln!(s, "\n## Arrays\n");
    let _ = writeln!(
        s,
        "| array | geometry | dataflow | requests | MACs | sim cycles | \
         queue peak | interconnect (uJ) | total (uJ) |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|");
    if let Some(Json::Arr(arrays)) = summary.get("per_array") {
        for a in arrays {
            let _ = writeln!(
                s,
                "| `{}` | {}x{} | {} | {} | {} | {} | {} | {:.2} | {:.2} |",
                text(a, "label"),
                num(a, "rows"),
                num(a, "cols"),
                text(a, "dataflow"),
                num(a, "requests"),
                num(a, "macs"),
                num(a, "sim_cycles"),
                num(a, "queue_peak"),
                num(a, "interconnect_uj"),
                num(a, "total_uj"),
            );
        }
    }
    let _ = writeln!(
        s,
        "\nEnergy: {:.2} uJ interconnect / {:.2} uJ total billed to \
         requests, plus {:.2} uJ of background cache warmup. Every \
         admitted request is billed exactly once (accepted == completed \
         == billed after drain).",
        num(summary, "interconnect_uj"),
        num(summary, "total_uj"),
        num(summary, "warmup_uj"),
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Matrix;
    use crate::sim::fast::simulate_gemm_fast;
    use crate::workloads::table1_layers;

    fn sample_rows() -> Vec<LayerPowerRow> {
        // Representative workload: long streams (M >> array fill/drain
        // overhead) with ReLU-profile sparsity, and the asymmetric aspect
        // derived from the *measured* activities via eq. 6 — exactly the
        // paper's procedure.
        let sa = SaConfig::paper_32x32();
        let tech = TechParams::default();
        let sym = PeGeometry::square(1000.0).unwrap();
        let mut rng = crate::util::rng::Rng::new(9);
        let sims: Vec<_> = (0..2)
            .map(|_| {
                let (m, k, n) = (512, 64, 40);
                let a = Matrix::from_vec(
                    m,
                    k,
                    (0..m * k)
                        .map(|_| {
                            // ReLU-profile: half the words are exact zeros.
                            if rng.chance(0.5) {
                                0
                            } else {
                                rng.int_range(0, 1999) as i32
                            }
                        })
                        .collect(),
                )
                .unwrap();
                let w = Matrix::from_vec(
                    k,
                    n,
                    (0..k * n).map(|_| rng.int_range(-2000, 1999) as i32).collect(),
                )
                .unwrap();
                simulate_gemm_fast(&sa, &a, &w).unwrap()
            })
            .collect();
        let n = sims.len() as f64;
        let a_h = sims.iter().map(|s| s.stats.horizontal.activity()).sum::<f64>() / n;
        let a_v = sims.iter().map(|s| s.stats.vertical.activity()).sum::<f64>() / n;
        let aspect = crate::floorplan::optimizer::closed_form_ratio(&sa, a_h, a_v);
        let asym = PeGeometry::new(1000.0, aspect).unwrap();
        sims.iter()
            .enumerate()
            .map(|(i, sim)| power_row(&format!("L{i}"), &sa, &tech, &sym, &asym, sim))
            .collect()
    }

    #[test]
    fn rows_show_positive_savings() {
        for r in sample_rows() {
            assert!(r.interconnect_reduction() > 0.0, "{}", r.name);
            assert!(r.total_reduction() > 0.0, "{}", r.name);
            assert!(r.total_reduction() < r.interconnect_reduction());
        }
    }

    #[test]
    fn average_row_is_mean() {
        let rows = sample_rows();
        let avg = average_row(&rows).unwrap();
        let want =
            (rows[0].sym.interconnect_mw() + rows[1].sym.interconnect_mw()) / 2.0;
        assert!((avg.sym.interconnect_mw() - want).abs() < 1e-9);
        assert_eq!(avg.name, "avg");
        assert!(average_row(&[]).is_none());
    }

    #[test]
    fn renderers_contain_layers() {
        let rows = sample_rows();
        let f4 = fig4_string(&rows);
        let f5 = fig5_string(&rows);
        assert!(f4.contains("L0") && f4.contains("L1"));
        assert!(f5.contains("L0") && f5.contains("interconnect") || !f5.is_empty());
        let csv = to_csv(&rows);
        assert_eq!(csv.lines().count(), 3); // header + 2 rows
        assert!(csv.starts_with("layer,"));
    }

    #[test]
    fn markdown_report_contains_sections() {
        let cfg = crate::config::ExperimentConfig::paper();
        let rows = sample_rows();
        let out = crate::report::pipeline::ExperimentOutput {
            rows: rows.clone(),
            average: average_row(&rows).unwrap(),
            aspect_used: 3.5,
            avg_activities: (0.24, 0.37),
            metrics: crate::coordinator::Metrics::default().snapshot(),
            used_runtime: false,
        };
        let md = markdown_report(&cfg, &table1_layers(), &out);
        assert!(md.contains("# asymm-sa experiment report"));
        assert!(md.contains("Table I"));
        assert!(md.contains("Fig. 4"));
        assert!(md.contains("Fig. 5"));
        assert!(md.contains("Timing"));
        assert!(md.contains("meets target"));
    }

    #[test]
    fn sweep_markdown_contains_sections() {
        use crate::explore::{DataflowKind, Explorer, SweepConfig, WorkloadKind};
        let cfg = SweepConfig {
            pe_budget: 16,
            aspect_points: 5,
            dataflows: vec![DataflowKind::Ws],
            workloads: vec![WorkloadKind::Synth],
            max_layers: 1,
            seed: 5,
            workers: 1,
            cache_capacity: 16,
            ..SweepConfig::default()
        };
        let out = Explorer::new(cfg.clone()).unwrap().run().unwrap();
        let md = sweep_markdown(&cfg, &out);
        assert!(md.contains("# asymm-sa design-space sweep"));
        assert!(md.contains("## Workload `synth`"));
        assert!(md.contains("Square 4x4 WS baseline"));
        assert!(md.contains("| geometry | dataflow |"));
        assert!(md.contains("Eq.-6 closed form"));
        assert!(md.contains("Cache traffic"));
    }

    #[test]
    fn fleet_markdown_contains_sections() {
        use crate::explore::WorkloadKind;
        use crate::fleet::{run_fleet_comparison, FleetConfig};
        let cfg = FleetConfig {
            pe_budget: 16,
            arrays: 2,
            workload: WorkloadKind::Synth,
            max_layers: 1,
            requests: 6,
            unique_inputs: 1,
            seed: 3,
            window: 3,
            cache_capacity: 8,
            workers: 1,
            ..FleetConfig::default()
        };
        let report = run_fleet_comparison(&cfg).unwrap();
        let md = fleet_markdown(&cfg, &report);
        assert!(md.contains("# asymm-sa fleet serving"));
        assert!(md.contains("## Provisioning"));
        assert!(md.contains("## Policy comparison"));
        assert!(md.contains("| heterogeneous | shape_affine |"));
        assert!(md.contains("| square | round_robin |"));
        assert!(md.contains("Headline:"));
    }

    #[test]
    fn chaos_markdown_contains_sections() {
        use crate::explore::WorkloadKind;
        use crate::faults::{run_chaos_comparison, ChaosConfig};
        use crate::fleet::FleetConfig;
        let ccfg = ChaosConfig {
            fleet: FleetConfig {
                pe_budget: 16,
                arrays: 2,
                workload: WorkloadKind::Synth,
                max_layers: 1,
                requests: 6,
                unique_inputs: 1,
                seed: 3,
                window: 3,
                cache_capacity: 8,
                workers: 1,
                ..FleetConfig::default()
            },
            scenarios: 1,
            ..ChaosConfig::default()
        };
        let report = run_chaos_comparison(&ccfg).unwrap();
        let md = chaos_markdown(&ccfg, &report);
        assert!(md.contains("# asymm-sa fault tolerance"));
        assert!(md.contains("Fault-free baseline"));
        assert!(md.contains("## Injected schedules"));
        assert!(md.contains("## Degradation vs fault-free"));
        assert!(md.contains("| scenario | completion |"));
        assert!(md.contains("Headline:"));
    }

    #[test]
    fn drift_markdown_contains_sections() {
        use crate::explore::WorkloadKind;
        use crate::fleet::{run_drift_comparison, ArrivalProcess, DriftConfig, FleetConfig};
        let dcfg = DriftConfig {
            fleet: FleetConfig {
                pe_budget: 16,
                arrays: 2,
                workload: WorkloadKind::Synth,
                max_layers: 2,
                requests: 24,
                unique_inputs: 2,
                seed: 11,
                window: 3,
                cache_capacity: 16,
                workers: 1,
                ..FleetConfig::default()
            },
            arrival: ArrivalProcess::Poisson { seed: 5, rate: 1.3 },
            phase_split: 0.5,
            detect_window: 6,
            divergence_threshold: 0.2,
        };
        let report = run_drift_comparison(&dcfg).unwrap();
        let md = drift_markdown(&dcfg, &report);
        assert!(md.contains("# asymm-sa drift adaptation"));
        assert!(md.contains("## Provisioning"));
        assert!(md.contains("## Adaptive vs static"));
        assert!(md.contains("| lane | adapted |"));
        assert!(md.contains("| adaptive | yes |"));
        assert!(md.contains("| static | no |"));
        assert!(md.contains("## Re-provisioned arrays"));
        assert!(md.contains("Headline:"));
    }

    #[test]
    fn table1_lists_all_six() {
        let s = table1_string(&table1_layers());
        for n in ["L1", "L2", "L3", "L4", "L5", "L6"] {
            assert!(s.contains(n));
        }
        assert!(s.contains("3136 x 256 x 64"));
    }

    #[test]
    fn daemon_markdown_renders_the_summary_document() {
        use crate::util::json::Json;
        let cfg = crate::daemon::DaemonConfig::default();
        let summary = Json::parse(
            r#"{
              "config": {"queue_bound": 12},
              "state": "drained",
              "clock_us": 420, "accepted": 9, "completed": 9, "billed": 9,
              "rejected": {"queue_full": 2, "deadline_exceeded": 1, "draining": 3},
              "reprovisions": 1, "warmup_uj": 0.5, "drain_latency_us": 37,
              "p50_us": 10, "p99_us": 20, "p999_us": 21,
              "per_class": [{"class": 0, "requests": 9, "p50_us": 10, "p99_us": 20, "p999_us": 21}],
              "interconnect_uj": 1.25, "total_uj": 4.5,
              "per_array": [{"label": "ws-8x2", "rows": 8, "cols": 2,
                "dataflow": "ws", "requests": 9, "macs": 100, "sim_cycles": 50,
                "queue_peak": 4, "interconnect_uj": 1.25, "total_uj": 4.5}]
            }"#,
        )
        .unwrap();
        let md = daemon_markdown(&cfg, &summary);
        assert!(md.contains("# asymm-sa serving daemon"));
        assert!(md.contains("## Accounting"));
        assert!(md.contains("| drained | 9 | 9 | 9 | 2 | 1 | 3 | 1 | 37 | 420 |"));
        assert!(md.contains("## Modeled latency"));
        assert!(md.contains("| all | 9 | 10 | 20 | 21 |"));
        assert!(md.contains("| 0 | 9 | 10 | 20 | 21 |"));
        assert!(md.contains("`ws-8x2`"));
        assert!(md.contains("8x2"));
        assert!(md.contains("accepted == completed == billed"));
    }
}
