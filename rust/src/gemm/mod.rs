//! GEMM substrate: matrices, reference matmul, im2col, SA tiling.
//!
//! Convolutions are lowered to the GEMM a weight-stationary SA executes
//! (paper §II): `Y[P×M] = patches[P×CK²] @ W[CK²×M]`, then the GEMM is
//! tiled onto the R×C array ([`tiling`]).

pub mod tiling;

pub use tiling::{TilePlan, TileStep};

use crate::error::{Error, Result};

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix<T> {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major backing store, `len == rows * cols`.
    pub data: Vec<T>,
}

impl<T: Copy + Default> Matrix<T> {
    /// Zero-initialized matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![T::default(); rows * cols],
        }
    }

    /// Build from a row-major vec. Errors if sizes disagree.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "matrix {}x{} needs {} elems, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix<T> {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.get(r, c));
            }
        }
        out
    }

    /// Copy a `bm x bn` block starting at `(r0, c0)`, zero-padded past the
    /// matrix edge (how the SA tiler pads ragged tiles).
    pub fn block_padded(&self, r0: usize, c0: usize, bm: usize, bn: usize) -> Matrix<T> {
        let mut out = Matrix::zeros(bm, bn);
        self.block_padded_into(r0, c0, &mut out);
        out
    }

    /// [`Matrix::block_padded`] into a caller-owned buffer whose shape
    /// picks the block size — lets tile loops double-buffer two tiles
    /// instead of allocating a fresh matrix per pass (the analytic
    /// engine's weight chain swaps a prev/cur pair every step).
    pub fn block_padded_into(&self, r0: usize, c0: usize, out: &mut Matrix<T>) {
        let (bm, bn) = (out.rows, out.cols);
        for v in out.data.iter_mut() {
            *v = T::default();
        }
        let copy_w = bn.min(self.cols.saturating_sub(c0));
        if copy_w == 0 {
            return; // block origin past the right edge: all padding
        }
        for r in 0..bm.min(self.rows.saturating_sub(r0)) {
            let src = (r0 + r) * self.cols + c0;
            out.data[r * bn..r * bn + copy_w].copy_from_slice(&self.data[src..src + copy_w]);
        }
    }
}

/// Reference integer GEMM with exact i64 accumulation: the oracle every
/// simulator result is checked against (mirrors `kernels.ref.matmul_ref`).
pub fn matmul_i64(a: &Matrix<i32>, w: &Matrix<i32>) -> Result<Matrix<i64>> {
    if a.cols != w.rows {
        return Err(Error::shape(format!(
            "inner dims mismatch: {}x{} @ {}x{}",
            a.rows, a.cols, w.rows, w.cols
        )));
    }
    let mut out = Matrix::zeros(a.rows, w.cols);
    for i in 0..a.rows {
        for j in 0..w.cols {
            let mut acc = 0i64;
            for k in 0..a.cols {
                acc += a.get(i, k) as i64 * w.get(k, j) as i64;
            }
            out.set(i, j, acc);
        }
    }
    Ok(out)
}

/// Reference f32 GEMM.
pub fn matmul_f32(a: &Matrix<f32>, w: &Matrix<f32>) -> Result<Matrix<f32>> {
    if a.cols != w.rows {
        return Err(Error::shape(format!(
            "inner dims mismatch: {}x{} @ {}x{}",
            a.rows, a.cols, w.rows, w.cols
        )));
    }
    let mut out = Matrix::zeros(a.rows, w.cols);
    for i in 0..a.rows {
        for j in 0..w.cols {
            let mut acc = 0f32;
            for k in 0..a.cols {
                acc += a.get(i, k) * w.get(k, j);
            }
            out.set(i, j, acc);
        }
    }
    Ok(out)
}

/// im2col for NCHW single-batch input: `(C,H,W)` → `(H_out·W_out, C·k²)`
/// with column order `(c, ki, kj)` — identical to `compile.model.im2col`.
pub fn im2col(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<Matrix<f32>> {
    if x.len() != c * h * w {
        return Err(Error::shape(format!(
            "input len {} != C*H*W = {}",
            x.len(),
            c * h * w
        )));
    }
    if stride == 0 || k == 0 {
        return Err(Error::shape("k and stride must be non-zero"));
    }
    let h_out = (h + 2 * pad - k) / stride + 1;
    let w_out = (w + 2 * pad - k) / stride + 1;
    let mut out = Matrix::zeros(h_out * w_out, c * k * k);
    for oy in 0..h_out {
        for ox in 0..w_out {
            let p = oy * w_out + ox;
            for ci in 0..c {
                for ki in 0..k {
                    for kj in 0..k {
                        let iy = (oy * stride + ki) as isize - pad as isize;
                        let ix = (ox * stride + kj) as isize - pad as isize;
                        let v = if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w {
                            x[ci * h * w + iy as usize * w + ix as usize]
                        } else {
                            0.0
                        };
                        out.set(p, ci * k * k + ki * k + kj, v);
                    }
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(m.get(0, 2), 3);
        assert_eq!(m.get(1, 0), 4);
        assert_eq!(m.row(1), &[4, 5, 6]);
        let t = m.transpose();
        assert_eq!(t.get(2, 0), 3);
        assert_eq!(t.get(0, 1), 4);
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 3, vec![1]).is_err());
    }

    #[test]
    fn block_padded_pads_with_zeros() {
        let m = Matrix::from_vec(2, 2, vec![1, 2, 3, 4]).unwrap();
        let b = m.block_padded(1, 1, 2, 2);
        assert_eq!(b.data, vec![4, 0, 0, 0]);
        let b2 = m.block_padded(0, 0, 2, 2);
        assert_eq!(b2.data, vec![1, 2, 3, 4]);
    }

    #[test]
    fn block_padded_into_clears_stale_contents() {
        // Reusing a dirty buffer must behave exactly like a fresh copy.
        let m = Matrix::from_vec(3, 3, (1..=9).collect()).unwrap();
        let mut buf = Matrix::from_vec(2, 2, vec![-7; 4]).unwrap();
        m.block_padded_into(2, 2, &mut buf);
        assert_eq!(buf, m.block_padded(2, 2, 2, 2));
        assert_eq!(buf.data, vec![9, 0, 0, 0]);
        m.block_padded_into(0, 1, &mut buf);
        assert_eq!(buf.data, vec![2, 3, 5, 6]);
        // Origin fully past the right edge: all padding, no panic.
        m.block_padded_into(0, 10, &mut buf);
        assert_eq!(buf.data, vec![0; 4]);
        assert_eq!(m.block_padded(0, 10, 2, 2).data, vec![0; 4]);
    }

    #[test]
    fn matmul_i64_known() {
        let a = Matrix::from_vec(2, 2, vec![1, 2, 3, 4]).unwrap();
        let w = Matrix::from_vec(2, 2, vec![1, 1, 1, 1]).unwrap();
        let y = matmul_i64(&a, &w).unwrap();
        assert_eq!(y.data, vec![3, 3, 7, 7]);
    }

    #[test]
    fn matmul_rejects_mismatch() {
        let a = Matrix::<i32>::zeros(2, 3);
        let w = Matrix::<i32>::zeros(2, 2);
        assert!(matmul_i64(&a, &w).is_err());
    }

    #[test]
    fn matmul_i64_no_overflow_at_int16_extremes() {
        // 64 products of int16 extremes: exceeds i32, exact in i64.
        let a = Matrix::from_vec(1, 64, vec![32767i32; 64]).unwrap();
        let w = Matrix::from_vec(64, 1, vec![-32768i32; 64]).unwrap();
        let y = matmul_i64(&a, &w).unwrap();
        assert_eq!(y.get(0, 0), 64 * 32767i64 * -32768i64);
    }

    #[test]
    fn im2col_identity_1x1() {
        // 1x1 kernel, no pad: patches are just the pixels, (H*W, C).
        let x: Vec<f32> = (0..2 * 3 * 3).map(|v| v as f32).collect();
        let p = im2col(&x, 2, 3, 3, 1, 1, 0).unwrap();
        assert_eq!(p.rows, 9);
        assert_eq!(p.cols, 2);
        assert_eq!(p.get(4, 0), x[4]);
        assert_eq!(p.get(4, 1), x[9 + 4]);
    }

    #[test]
    fn im2col_3x3_center_and_corner() {
        let x: Vec<f32> = (0..25).map(|v| v as f32).collect();
        let p = im2col(&x, 1, 5, 5, 3, 1, 1).unwrap();
        assert_eq!(p.rows, 25);
        assert_eq!(p.cols, 9);
        // Center output (2,2): column (ki=1,kj=1) = x[2,2] = 12.
        assert_eq!(p.get(12, 4), 12.0);
        // Corner output (0,0): column (ki=0,kj=0) hits pad → 0.
        assert_eq!(p.get(0, 0), 0.0);
        // Corner output (0,0): column (ki=1,kj=1) = x[0,0] = 0.
        assert_eq!(p.get(0, 4), 0.0);
        // Corner output (0,0): column (ki=2,kj=2) = x[1,1] = 6.
        assert_eq!(p.get(0, 8), 6.0);
    }

    #[test]
    fn im2col_matches_direct_conv() {
        // conv(x, w) via im2col @ w_flat equals direct convolution.
        let (c, h, w, k) = (2usize, 4usize, 4usize, 3usize);
        let x: Vec<f32> = (0..c * h * w).map(|v| (v as f32 * 0.37).sin()).collect();
        let wgt: Vec<f32> = (0..c * k * k).map(|v| (v as f32 * 0.11).cos()).collect();
        let patches = im2col(&x, c, h, w, k, 1, 1).unwrap();
        let wmat = Matrix::from_vec(c * k * k, 1, wgt.clone()).unwrap();
        let y = matmul_f32(&patches, &wmat).unwrap();
        // Direct conv at output (1,2):
        let (oy, ox) = (1isize, 2isize);
        let mut want = 0f32;
        for ci in 0..c {
            for ki in 0..k {
                for kj in 0..k {
                    let iy = oy + ki as isize - 1;
                    let ix = ox + kj as isize - 1;
                    if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w {
                        want += x[ci * h * w + iy as usize * w + ix as usize]
                            * wgt[ci * k * k + ki * k + kj];
                    }
                }
            }
        }
        let got = y.get(oy as usize * w + ox as usize, 0);
        assert!((got - want).abs() < 1e-4, "{got} vs {want}");
    }

    #[test]
    fn im2col_rejects_bad_input() {
        assert!(im2col(&[0.0; 3], 2, 3, 3, 1, 1, 0).is_err());
        assert!(im2col(&[0.0; 9], 1, 3, 3, 0, 1, 0).is_err());
        assert!(im2col(&[0.0; 9], 1, 3, 3, 1, 0, 0).is_err());
    }
}
