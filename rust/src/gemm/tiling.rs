//! Tiling of an arbitrary GEMM onto an R×C weight-stationary array.
//!
//! A `(M_g × K_g × N_g)` GEMM runs as a sequence of *tile passes*: each
//! pass preloads one `R×C` weight block `W[k0..k0+R, n0..n0+C]` and
//! streams all `M_g` activation rows against it. Pass order is chosen to
//! maximize weight reuse (the WS rationale, paper §II): all `k` blocks of
//! one `n` block-column run back-to-back so the column's partial sums are
//! accumulated across consecutive passes.


use crate::arch::SaConfig;
use crate::error::{Error, Result};

/// One weight-stationary tile pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileStep {
    /// Starting reduction index of the weight block (`k0`).
    pub k0: usize,
    /// Starting output-channel index of the weight block (`n0`).
    pub n0: usize,
    /// Rows of the weight block actually used (`≤ R`; edge tiles ragged).
    pub k_len: usize,
    /// Columns of the weight block actually used (`≤ C`).
    pub n_len: usize,
    /// Whether this pass starts a fresh accumulation for its `n` block
    /// (first `k` block of the column) — later passes add to it.
    pub first_k: bool,
}

/// Complete schedule of tile passes for one GEMM on one array.
#[derive(Debug, Clone, PartialEq)]
pub struct TilePlan {
    /// GEMM rows streamed per pass (`M_g`).
    pub m: usize,
    /// GEMM reduction size (`K_g`).
    pub k: usize,
    /// GEMM output channels (`N_g`).
    pub n: usize,
    /// Array rows (R) — reduction indices per pass.
    pub array_rows: usize,
    /// Array cols (C) — output channels per pass.
    pub array_cols: usize,
    /// Ordered tile passes.
    pub steps: Vec<TileStep>,
}

impl TilePlan {
    /// Build the WS schedule for GEMM `(m × k × n)` on array `sa`.
    pub fn new(m: usize, k: usize, n: usize, sa: &SaConfig) -> Result<Self> {
        if m == 0 || k == 0 || n == 0 {
            return Err(Error::shape(format!("degenerate GEMM {m}x{k}x{n}")));
        }
        let (r, c) = (sa.rows, sa.cols);
        let mut steps = Vec::new();
        let mut n0 = 0;
        while n0 < n {
            let n_len = c.min(n - n0);
            let mut k0 = 0;
            while k0 < k {
                let k_len = r.min(k - k0);
                steps.push(TileStep {
                    k0,
                    n0,
                    k_len,
                    n_len,
                    first_k: k0 == 0,
                });
                k0 += r;
            }
            n0 += c;
        }
        Ok(TilePlan {
            m,
            k,
            n,
            array_rows: r,
            array_cols: c,
            steps,
        })
    }

    /// Number of tile passes.
    pub fn num_passes(&self) -> usize {
        self.steps.len()
    }

    /// Total cycles on the array under the WS timing model.
    pub fn total_cycles(&self, sa: &SaConfig) -> usize {
        self.steps.len() * sa.ws_tile_cycles(self.m)
    }

    /// Total MAC operations actually performed (ragged tiles excluded).
    pub fn total_macs(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| self.m as u64 * s.k_len as u64 * s.n_len as u64)
            .sum()
    }

    /// Array utilization: useful MACs / (PEs × cycles spent streaming).
    pub fn utilization(&self, sa: &SaConfig) -> f64 {
        let ideal = (sa.num_pes() * self.total_cycles(sa)) as f64;
        self.total_macs() as f64 / ideal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sa() -> SaConfig {
        SaConfig::paper_32x32()
    }

    #[test]
    fn exact_fit_single_pass() {
        let plan = TilePlan::new(100, 32, 32, &sa()).unwrap();
        assert_eq!(plan.num_passes(), 1);
        let s = plan.steps[0];
        assert_eq!((s.k0, s.n0, s.k_len, s.n_len), (0, 0, 32, 32));
        assert!(s.first_k);
    }

    #[test]
    fn k_blocks_run_back_to_back_within_column() {
        // K=96 (3 blocks), N=64 (2 block-cols) → 6 passes, k-major inside n.
        let plan = TilePlan::new(10, 96, 64, &sa()).unwrap();
        assert_eq!(plan.num_passes(), 6);
        let order: Vec<(usize, usize, bool)> =
            plan.steps.iter().map(|s| (s.n0, s.k0, s.first_k)).collect();
        assert_eq!(
            order,
            vec![
                (0, 0, true),
                (0, 32, false),
                (0, 64, false),
                (32, 0, true),
                (32, 32, false),
                (32, 64, false),
            ]
        );
    }

    #[test]
    fn ragged_edges() {
        let plan = TilePlan::new(5, 33, 40, &sa()).unwrap();
        assert_eq!(plan.num_passes(), 4);
        assert_eq!(plan.steps[1].k_len, 1); // 33 = 32 + 1
        assert_eq!(plan.steps[1].n_len, 32);
        assert_eq!(plan.steps[2].n_len, 8); // 40 = 32 + 8
        // MACs: m * (33 * 40) regardless of padding.
        assert_eq!(plan.total_macs(), 5 * 33 * 40);
    }

    #[test]
    fn table1_l1_pass_count() {
        // L1: 3136x256x64 on 32x32 → ceil(256/32)*ceil(64/32) = 8*2 = 16.
        let plan = TilePlan::new(3136, 256, 64, &sa()).unwrap();
        assert_eq!(plan.num_passes(), 16);
        assert_eq!(plan.total_macs(), 3136 * 256 * 64);
    }

    #[test]
    fn utilization_bounds() {
        let full = TilePlan::new(1000, 64, 64, &sa()).unwrap();
        let u = full.utilization(&sa());
        assert!(u > 0.5 && u <= 1.0, "utilization {u}");
        // Tiny GEMM wastes most of the array.
        let tiny = TilePlan::new(1, 1, 1, &sa()).unwrap();
        assert!(tiny.utilization(&sa()) < 0.01);
    }

    #[test]
    fn rejects_degenerate() {
        assert!(TilePlan::new(0, 1, 1, &sa()).is_err());
        assert!(TilePlan::new(1, 0, 1, &sa()).is_err());
        assert!(TilePlan::new(1, 1, 0, &sa()).is_err());
    }
}
