//! Leveled, machine-consumable stderr logging.
//!
//! The daemon's operational chatter goes through this sink instead of
//! raw `eprintln!`, so socket-mode stderr is parseable (logfmt: one
//! `level=… component=… msg="…"` line per event) and `--quiet` can
//! silence everything below [`Level::Error`]. Formatting is a pure
//! function ([`format_line`]) so tests can assert on it without
//! capturing stderr.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ascending.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Developer noise.
    Debug = 0,
    /// Normal operational events (default threshold).
    Info = 1,
    /// Degraded but continuing.
    Warn = 2,
    /// Failures; never silenced by `--quiet`.
    Error = 3,
}

impl Level {
    /// Stable lowercase name used in the logfmt line.
    pub fn name(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

static MIN_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global minimum level (lower levels are dropped).
pub fn set_level(level: Level) {
    MIN_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current minimum level.
pub fn min_level() -> Level {
    match MIN_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

/// Render one logfmt line: `level=info component=daemon msg="…"`.
/// Quotes and backslashes in the message are escaped so one event is
/// always exactly one parseable line.
pub fn format_line(level: Level, component: &str, msg: &str) -> String {
    let mut escaped = String::with_capacity(msg.len());
    for ch in msg.chars() {
        match ch {
            '"' => escaped.push_str("\\\""),
            '\\' => escaped.push_str("\\\\"),
            '\n' => escaped.push_str("\\n"),
            c => escaped.push(c),
        }
    }
    format!("level={} component={component} msg=\"{escaped}\"", level.name())
}

/// Emit one event to stderr if it clears the threshold.
pub fn log(level: Level, component: &str, msg: &str) {
    if level >= min_level() {
        eprintln!("{}", format_line(level, component, msg));
    }
}

/// [`Level::Info`] event.
pub fn info(component: &str, msg: &str) {
    log(Level::Info, component, msg);
}

/// [`Level::Warn`] event.
pub fn warn(component: &str, msg: &str) {
    log(Level::Warn, component, msg);
}

/// [`Level::Error`] event.
pub fn error(component: &str, msg: &str) {
    log(Level::Error, component, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_is_logfmt_with_escapes() {
        assert_eq!(
            format_line(Level::Info, "daemon", "listening on /tmp/x.sock"),
            "level=info component=daemon msg=\"listening on /tmp/x.sock\""
        );
        assert_eq!(
            format_line(Level::Error, "daemon", "a \"quoted\"\npath\\x"),
            "level=error component=daemon msg=\"a \\\"quoted\\\"\\npath\\\\x\""
        );
    }

    #[test]
    fn levels_order_and_name() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Warn < Level::Error);
        assert_eq!(Level::Warn.name(), "warn");
    }
}
