//! Deterministic observability: modeled-time span tracing + a unified
//! metrics registry.
//!
//! Everything here obeys the repo's determinism contract: spans carry
//! **modeled** begin/end instants (µs on the admission clock), never
//! wall-clock timestamps, so a trace export is a pure function of
//! `(config, request script)` and byte-identical at any worker count —
//! pinned by `tests/trace_determinism.rs`. The [`Tracer`] records typed
//! spans (`admit`, `route`, `queue_wait`, `batch`, `cache_lookup`,
//! `engine`, `retry`, `failover`, `warmup`, `reprovision`, `drain`,
//! `bill`) plus cause-typed rejection events, and exports Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto) via
//! [`Tracer::chrome_string`]. The [`Registry`] absorbs the scattered
//! counters (cache tiers, retries, failovers, sheds by cause, drift
//! divergence, warmup energy) behind one Prometheus-style text
//! exposition ([`Registry::render_text`]) with fixed log-spaced
//! histogram buckets, surfaced on the wire as the daemon's
//! `get_metrics` method. `docs/observability.md` is the naming
//! reference.

pub mod log;

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::{obj, Json};

/// What a span measures. `name()` is the wire/export name; the span
/// vocabulary is documented in `docs/observability.md`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Admission decision for one request (instant at arrival).
    Admit,
    /// Routing decision (instant; `array` is the chosen slot).
    Route,
    /// Time between arrival and service start on the routed array.
    QueueWait,
    /// One admission-window flush serving a batch.
    Batch,
    /// Result-cache lookup (instant; hit/miss is a metric, not a span).
    CacheLookup,
    /// Modeled service on the array (start..finish).
    Engine,
    /// One bounded modeled-time retry after a fault (chaos path).
    Retry,
    /// Fault-masked failover re-route (chaos path).
    Failover,
    /// Background cache warmup job.
    Warmup,
    /// Drift-triggered re-provisioning cutover.
    Reprovision,
    /// Graceful drain (drain instant .. modeled busy horizon).
    Drain,
    /// Terminal billing event: the request completed and was billed.
    Bill,
}

impl SpanKind {
    /// Stable export name.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::Route => "route",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::Batch => "batch",
            SpanKind::CacheLookup => "cache_lookup",
            SpanKind::Engine => "engine",
            SpanKind::Retry => "retry",
            SpanKind::Failover => "failover",
            SpanKind::Warmup => "warmup",
            SpanKind::Reprovision => "reprovision",
            SpanKind::Drain => "drain",
            SpanKind::Bill => "bill",
        }
    }

    /// All kinds, in exposition order.
    pub const ALL: [SpanKind; 12] = [
        SpanKind::Admit,
        SpanKind::Route,
        SpanKind::QueueWait,
        SpanKind::Batch,
        SpanKind::CacheLookup,
        SpanKind::Engine,
        SpanKind::Retry,
        SpanKind::Failover,
        SpanKind::Warmup,
        SpanKind::Reprovision,
        SpanKind::Drain,
        SpanKind::Bill,
    ];
}

/// Why an arrival was shed. Mirrors the wire error codes of
/// `docs/protocol.md` exactly, so trace events and error counters
/// cannot drift apart.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectCause {
    /// Bounded queue hit this class's watermark.
    QueueFull,
    /// Projected modeled sojourn exceeds the deadline.
    DeadlineExceeded,
    /// The server is draining (or drained) and sheds all new work.
    Draining,
}

impl RejectCause {
    /// Stable export name — identical to the wire error code.
    pub fn name(self) -> &'static str {
        match self {
            RejectCause::QueueFull => "queue_full",
            RejectCause::DeadlineExceeded => "deadline_exceeded",
            RejectCause::Draining => "draining",
        }
    }

    /// All causes, in exposition order.
    pub const ALL: [RejectCause; 3] = [
        RejectCause::QueueFull,
        RejectCause::DeadlineExceeded,
        RejectCause::Draining,
    ];
}

/// One recorded span: a typed interval on the modeled clock.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// What this span measures.
    pub kind: SpanKind,
    /// Modeled begin instant (µs).
    pub begin_us: u64,
    /// Modeled end instant (µs); equal to `begin_us` for instants.
    pub end_us: u64,
    /// Track (Chrome `pid`) the span belongs to.
    pub track: usize,
    /// Request id, when the span is attributable to one request.
    pub request: Option<u64>,
    /// Priority class, when known.
    pub class: Option<u8>,
    /// Array slot, when the span is attributable to one array.
    pub array: Option<usize>,
}

impl Span {
    /// Attach a request id (builder style).
    pub fn request(&mut self, id: u64) -> &mut Self {
        self.request = Some(id);
        self
    }

    /// Attach a priority class (builder style).
    pub fn class(&mut self, class: u8) -> &mut Self {
        self.class = Some(class);
        self
    }

    /// Attach an array slot (builder style).
    pub fn array(&mut self, array: usize) -> &mut Self {
        self.array = Some(array);
        self
    }
}

/// One cause-typed rejection event (an instant on the modeled clock).
#[derive(Debug, Clone, PartialEq)]
pub struct Reject {
    /// Why the arrival was shed.
    pub cause: RejectCause,
    /// Modeled arrival instant (µs).
    pub t_us: u64,
    /// Track (Chrome `pid`) the event belongs to.
    pub track: usize,
    /// Request id, when one was assigned before the rejection.
    pub request: Option<u64>,
    /// Priority class, when known.
    pub class: Option<u8>,
    /// Array the request was routed to, when routing ran.
    pub array: Option<usize>,
}

impl Reject {
    /// Attach a request id (builder style).
    pub fn request(&mut self, id: u64) -> &mut Self {
        self.request = Some(id);
        self
    }

    /// Attach a priority class (builder style).
    pub fn class(&mut self, class: u8) -> &mut Self {
        self.class = Some(class);
        self
    }

    /// Attach an array slot (builder style).
    pub fn array(&mut self, array: usize) -> &mut Self {
        self.array = Some(array);
        self
    }
}

/// Records modeled-time spans and rejection events, grouped into named
/// tracks (one Chrome `pid` per track: a policy lane, a drift lane, or
/// the daemon itself). A disabled tracer ([`Tracer::off`]) accepts the
/// same calls at near-zero cost — recording methods write to a scratch
/// slot — so call sites need no `if traced` branches.
#[derive(Debug, Clone)]
pub struct Tracer {
    enabled: bool,
    tracks: Vec<String>,
    current: usize,
    spans: Vec<Span>,
    rejects: Vec<Reject>,
    scratch_span: Span,
    scratch_reject: Reject,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    /// An enabled tracer. Tracks are created on first use; recording a
    /// span before any [`Tracer::track`] call lands on a default
    /// `main` track.
    pub fn new() -> Self {
        Tracer {
            enabled: true,
            tracks: Vec::new(),
            current: 0,
            spans: Vec::new(),
            rejects: Vec::new(),
            scratch_span: Span {
                kind: SpanKind::Admit,
                begin_us: 0,
                end_us: 0,
                track: 0,
                request: None,
                class: None,
                array: None,
            },
            scratch_reject: Reject {
                cause: RejectCause::QueueFull,
                t_us: 0,
                track: 0,
                request: None,
                class: None,
                array: None,
            },
        }
    }

    /// A disabled tracer: every recording call is a cheap no-op.
    pub fn off() -> Self {
        let mut t = Self::new();
        t.enabled = false;
        t
    }

    /// Whether spans are being kept.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Switch the current track, creating it on first use. Track order
    /// is first-use order — deterministic because every caller runs on
    /// the sequential orchestration path.
    pub fn track(&mut self, name: &str) {
        if !self.enabled {
            return;
        }
        self.current = match self.tracks.iter().position(|t| t == name) {
            Some(i) => i,
            None => {
                self.tracks.push(name.to_string());
                self.tracks.len() - 1
            }
        };
    }

    /// Lazily create the default track when recording starts before any
    /// [`Tracer::track`] call.
    fn ensure_track(&mut self) {
        if self.tracks.is_empty() {
            self.tracks.push("main".to_string());
            self.current = 0;
        }
    }

    /// Record a span on the current track and return it for builder-style
    /// attribution. `end_us < begin_us` is clamped to an instant.
    pub fn span(&mut self, kind: SpanKind, begin_us: u64, end_us: u64) -> &mut Span {
        if !self.enabled {
            return &mut self.scratch_span;
        }
        self.ensure_track();
        self.spans.push(Span {
            kind,
            begin_us,
            end_us: end_us.max(begin_us),
            track: self.current,
            request: None,
            class: None,
            array: None,
        });
        self.spans.last_mut().expect("just pushed")
    }

    /// Record an instant span (begin == end).
    pub fn instant(&mut self, kind: SpanKind, t_us: u64) -> &mut Span {
        self.span(kind, t_us, t_us)
    }

    /// Record a cause-typed rejection event on the current track.
    pub fn reject(&mut self, cause: RejectCause, t_us: u64) -> &mut Reject {
        if !self.enabled {
            return &mut self.scratch_reject;
        }
        self.ensure_track();
        self.rejects.push(Reject {
            cause,
            t_us,
            track: self.current,
            request: None,
            class: None,
            array: None,
        });
        self.rejects.last_mut().expect("just pushed")
    }

    /// Recorded spans, in recording order.
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Recorded rejection events, in recording order.
    pub fn rejects(&self) -> &[Reject] {
        &self.rejects
    }

    /// Track names, in first-use order.
    pub fn tracks(&self) -> &[String] {
        &self.tracks
    }

    /// Count of spans of one kind.
    pub fn count(&self, kind: SpanKind) -> usize {
        self.spans.iter().filter(|s| s.kind == kind).count()
    }

    /// Count of rejection events of one cause.
    pub fn reject_count(&self, cause: RejectCause) -> usize {
        self.rejects.iter().filter(|r| r.cause == cause).count()
    }

    /// Export as Chrome trace-event JSON (loadable in `chrome://tracing`
    /// and Perfetto). `pid` is the track index, `tid` the array slot
    /// (+1; 0 = no array). All `ts`/`dur` are modeled µs — never
    /// wall-clock — so the export is byte-identical at any worker count.
    pub fn chrome_string(&self) -> String {
        let mut events = Vec::new();
        for (i, name) in self.tracks.iter().enumerate() {
            events.push(obj(vec![
                ("ph", Json::Str("M".to_string())),
                ("pid", Json::Num(i as f64)),
                ("tid", Json::Num(0.0)),
                ("name", Json::Str("process_name".to_string())),
                ("args", obj(vec![("name", Json::Str(name.clone()))])),
            ]));
        }
        for s in &self.spans {
            let mut args = Vec::new();
            if let Some(r) = s.request {
                args.push(("request", Json::Num(r as f64)));
            }
            if let Some(c) = s.class {
                args.push(("class", Json::Num(c as f64)));
            }
            if let Some(a) = s.array {
                args.push(("array", Json::Num(a as f64)));
            }
            let mut ev = vec![
                ("ph", Json::Str("X".to_string())),
                ("pid", Json::Num(s.track as f64)),
                (
                    "tid",
                    Json::Num(s.array.map(|a| a as f64 + 1.0).unwrap_or(0.0)),
                ),
                ("ts", Json::Num(s.begin_us as f64)),
                ("dur", Json::Num((s.end_us - s.begin_us) as f64)),
                ("name", Json::Str(s.kind.name().to_string())),
                ("cat", Json::Str("span".to_string())),
            ];
            ev.push(("args", obj(args)));
            events.push(obj(ev));
        }
        for r in &self.rejects {
            let mut args = Vec::new();
            if let Some(id) = r.request {
                args.push(("request", Json::Num(id as f64)));
            }
            if let Some(c) = r.class {
                args.push(("class", Json::Num(c as f64)));
            }
            if let Some(a) = r.array {
                args.push(("array", Json::Num(a as f64)));
            }
            events.push(obj(vec![
                ("ph", Json::Str("i".to_string())),
                ("pid", Json::Num(r.track as f64)),
                (
                    "tid",
                    Json::Num(r.array.map(|a| a as f64 + 1.0).unwrap_or(0.0)),
                ),
                ("ts", Json::Num(r.t_us as f64)),
                ("name", Json::Str(format!("reject:{}", r.cause.name()))),
                ("cat", Json::Str("reject".to_string())),
                ("s", Json::Str("t".to_string())),
                ("args", obj(args)),
            ]));
        }
        obj(vec![
            ("displayTimeUnit", Json::Str("ms".to_string())),
            ("traceEvents", Json::Arr(events)),
        ])
        .to_string()
    }
}

/// The fixed log-spaced bucket edges every latency histogram uses:
/// 1-2-5 per decade over 1 µs .. 10 s of modeled time. Fixed edges keep
/// the exposition deterministic — bucket boundaries never depend on the
/// data.
pub fn latency_edges_us() -> Vec<f64> {
    let mut edges = Vec::new();
    let mut decade = 1.0;
    while decade <= 1e7 {
        for m in [1.0, 2.0, 5.0] {
            edges.push(decade * m);
        }
        decade *= 10.0;
    }
    edges
}

/// A fixed-bucket histogram (cumulative exposition like Prometheus).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    edges: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    /// A histogram over the given ascending bucket edges.
    pub fn new(edges: Vec<f64>) -> Self {
        let n = edges.len();
        Histogram {
            edges,
            counts: vec![0; n],
            sum: 0.0,
            count: 0,
        }
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        for (i, e) in self.edges.iter().enumerate() {
            if v <= *e {
                self.counts[i] += 1;
                break;
            }
        }
        self.sum += v;
        self.count += 1;
    }

    /// Total observation count.
    pub fn count(&self) -> u64 {
        self.count
    }
}

/// Format a number the way `util::json` does: integral values print
/// without a fractional part, so expositions diff cleanly against JSON
/// artifacts.
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 2f64.powi(53) {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Base metric name: everything before the `{...}` label block.
fn base_name(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// A unified metrics registry: typed counters, gauges and histograms
/// behind one deterministic Prometheus-style text exposition. Metric
/// names carry their labels inline (`daemon_rejected_total{cause=
/// "queue_full"}`); `BTreeMap` storage makes exposition order canonical.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `delta` to a counter (created at 0).
    pub fn add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Increment a counter by 1.
    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    /// Read a counter (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a gauge to the current value.
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Read a gauge (0 when never set).
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Record one observation into a histogram (created on first use
    /// with the fixed [`latency_edges_us`] buckets).
    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(latency_edges_us()))
            .observe(v);
    }

    /// Read a histogram's observation count (0 when never touched).
    pub fn hist_count(&self, name: &str) -> u64 {
        self.hists.get(name).map(|h| h.count()).unwrap_or(0)
    }

    /// A registry derived purely from a tracer's recorded events:
    /// `trace_spans_total{kind=...}` per span kind,
    /// `trace_rejects_total{cause=...}` per cause (every kind/cause
    /// pre-listed at 0 so the exposition shape never depends on the
    /// run), modeled-duration histograms for `engine` and `queue_wait`
    /// spans, and a `trace_tracks` gauge. The one-shot CLI commands
    /// build their `.prom` sibling from this — a pure function of the
    /// trace, so it inherits the trace's worker-count byte-identity.
    pub fn from_tracer(t: &Tracer) -> Self {
        let mut r = Registry::new();
        for kind in SpanKind::ALL {
            r.add(&format!("trace_spans_total{{kind=\"{}\"}}", kind.name()), 0);
        }
        for cause in RejectCause::ALL {
            r.add(&format!("trace_rejects_total{{cause=\"{}\"}}", cause.name()), 0);
        }
        for s in t.spans() {
            r.inc(&format!("trace_spans_total{{kind=\"{}\"}}", s.kind.name()));
            match s.kind {
                SpanKind::Engine => r.observe("trace_engine_us", (s.end_us - s.begin_us) as f64),
                SpanKind::QueueWait => {
                    r.observe("trace_queue_wait_us", (s.end_us - s.begin_us) as f64)
                }
                _ => {}
            }
        }
        for rej in t.rejects() {
            r.inc(&format!("trace_rejects_total{{cause=\"{}\"}}", rej.cause.name()));
        }
        r.set_gauge("trace_tracks", t.tracks().len() as f64);
        r
    }

    /// Render the Prometheus text exposition: `# TYPE` headers, sorted
    /// metric lines, cumulative histogram buckets. Deterministic: the
    /// same registry state renders byte-identically.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        let mut last_base = "";
        for (name, v) in &self.counters {
            let b = base_name(name);
            if b != last_base {
                let _ = writeln!(s, "# TYPE {b} counter");
                last_base = b;
            }
            let _ = writeln!(s, "{name} {v}");
        }
        last_base = "";
        for (name, v) in &self.gauges {
            let b = base_name(name);
            if b != last_base {
                let _ = writeln!(s, "# TYPE {b} gauge");
                last_base = b;
            }
            let _ = writeln!(s, "{name} {}", fmt_num(*v));
        }
        for (name, h) in &self.hists {
            let _ = writeln!(s, "# TYPE {name} histogram");
            let mut cum = 0u64;
            for (e, c) in h.edges.iter().zip(&h.counts) {
                cum += c;
                let _ = writeln!(s, "{name}_bucket{{le=\"{}\"}} {cum}", fmt_num(*e));
            }
            let _ = writeln!(s, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
            let _ = writeln!(s, "{name}_sum {}", fmt_num(h.sum));
            let _ = writeln!(s, "{name}_count {}", h.count);
        }
        s
    }
}

/// Write the three sibling trace artifacts for a run: the Chrome trace
/// at `path`, the metrics exposition at `path` with extension `prom`,
/// and the critical-path digest at `path` with extension `md`. Returns
/// the three paths written.
pub fn write_trace_artifacts(
    path: &std::path::Path,
    tracer: &Tracer,
    registry: &Registry,
) -> crate::error::Result<Vec<std::path::PathBuf>> {
    let write = |p: &std::path::Path, text: &str| -> crate::error::Result<()> {
        if let Some(dir) = p.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(p, text)?;
        Ok(())
    };
    let prom = path.with_extension("prom");
    let md = path.with_extension("md");
    write(path, &tracer.chrome_string())?;
    write(&prom, &registry.render_text())?;
    write(&md, &crate::report::trace_markdown(tracer))?;
    Ok(vec![path.to_path_buf(), prom, md])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_with_attribution() {
        let mut t = Tracer::new();
        t.track("lane");
        t.span(SpanKind::Engine, 10, 30).request(7).class(1).array(2);
        t.reject(RejectCause::QueueFull, 40).request(8).class(0);
        assert_eq!(t.count(SpanKind::Engine), 1);
        assert_eq!(t.reject_count(RejectCause::QueueFull), 1);
        let s = &t.spans()[0];
        assert_eq!((s.begin_us, s.end_us), (10, 30));
        assert_eq!((s.request, s.class, s.array), (Some(7), Some(1), Some(2)));
        assert_eq!(t.tracks(), &["lane".to_string()]);
    }

    #[test]
    fn recording_before_any_track_call_lands_on_main() {
        let mut t = Tracer::new();
        t.instant(SpanKind::Admit, 1);
        assert_eq!(t.tracks(), &["main".to_string()]);
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::off();
        t.track("lane");
        t.span(SpanKind::Bill, 1, 2).request(1);
        t.reject(RejectCause::Draining, 3);
        assert!(t.spans().is_empty());
        assert!(t.rejects().is_empty());
        assert!(!t.is_enabled());
    }

    #[test]
    fn chrome_export_is_valid_json_with_metadata_and_events() {
        let mut t = Tracer::new();
        t.track("daemon");
        t.span(SpanKind::Engine, 5, 9).request(1).array(0);
        t.instant(SpanKind::Bill, 9).request(1);
        t.reject(RejectCause::DeadlineExceeded, 12).class(1);
        let s = t.chrome_string();
        let j = Json::parse(&s).unwrap();
        let events = j.req("traceEvents").unwrap().as_arr().unwrap();
        // 1 track's metadata + 2 spans + 1 reject.
        assert_eq!(events.len(), 4);
        assert!(s.contains(r#""displayTimeUnit":"ms""#));
        assert!(s.contains(r#""name":"reject:deadline_exceeded""#));
        assert!(s.contains(r#""ph":"X""#));
        // tid 1 = array 0; instants without an array sit on tid 0.
        assert!(s.contains(r#""tid":1"#));
    }

    #[test]
    fn span_end_clamps_to_begin() {
        let mut t = Tracer::new();
        t.span(SpanKind::QueueWait, 10, 5);
        assert_eq!(t.spans()[0].end_us, 10);
    }

    #[test]
    fn registry_counts_and_renders_deterministically() {
        let mut r = Registry::new();
        r.inc("x_total{cause=\"b\"}");
        r.inc("x_total{cause=\"a\"}");
        r.add("x_total{cause=\"a\"}", 2);
        r.set_gauge("g_value", 2.5);
        r.observe("lat_us", 3.0);
        r.observe("lat_us", 700.0);
        let text = r.render_text();
        // BTreeMap order: label a before label b, one TYPE header.
        let a = text.find("x_total{cause=\"a\"} 3").unwrap();
        let b = text.find("x_total{cause=\"b\"} 1").unwrap();
        assert!(a < b);
        assert_eq!(text.matches("# TYPE x_total counter").count(), 1);
        assert!(text.contains("g_value 2.5"));
        assert!(text.contains("lat_us_bucket{le=\"5\"} 1"));
        assert!(text.contains("lat_us_bucket{le=\"1000\"} 2"));
        assert!(text.contains("lat_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("lat_us_sum 703"));
        assert!(text.contains("lat_us_count 2"));
        assert_eq!(text, r.clone().render_text());
        assert_eq!(r.counter("x_total{cause=\"a\"}"), 3);
        assert_eq!(r.hist_count("lat_us"), 2);
    }

    #[test]
    fn registry_from_tracer_counts_spans_and_rejects() {
        let mut t = Tracer::new();
        t.span(SpanKind::Engine, 0, 10).request(1);
        t.span(SpanKind::QueueWait, 0, 4);
        t.reject(RejectCause::Draining, 5);
        let r = Registry::from_tracer(&t);
        assert_eq!(r.counter("trace_spans_total{kind=\"engine\"}"), 1);
        assert_eq!(r.counter("trace_spans_total{kind=\"bill\"}"), 0);
        assert_eq!(r.counter("trace_rejects_total{cause=\"draining\"}"), 1);
        assert_eq!(r.hist_count("trace_engine_us"), 1);
        assert_eq!(r.gauge("trace_tracks"), 1.0);
        // The exposition lists every kind regardless of what ran.
        let text = r.render_text();
        for kind in SpanKind::ALL {
            assert!(text.contains(&format!("trace_spans_total{{kind=\"{}\"}}", kind.name())));
        }
    }

    #[test]
    fn latency_edges_are_ascending_one_two_five() {
        let e = latency_edges_us();
        assert_eq!(&e[..6], &[1.0, 2.0, 5.0, 10.0, 20.0, 50.0]);
        assert!(e.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*e.last().unwrap(), 5e7);
    }
}
