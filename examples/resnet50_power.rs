//! **End-to-end driver** — the paper's §IV experiment, all layers of the
//! stack composed (EXPERIMENTS.md records a run of this binary):
//!
//! 1. For each Table-I ResNet50 layer, synthesize a realistic post-ReLU
//!    input tensor and He-init weights (the ImageNet substitution,
//!    DESIGN.md §3).
//! 2. Execute the layer forward through the **AOT-compiled JAX/Pallas
//!    artifact via PJRT** (L1+L2): the artifact returns the activations
//!    *and* the int16-quantized im2col patches — the exact words the WS
//!    array streams.
//! 3. Simulate every GEMM on the 32×32 WS array with the thread-pool
//!    coordinator (L3), collecting exact per-wire toggle statistics.
//! 4. Derive the asymmetric aspect ratio from the measured average
//!    activities (eq. 6) and evaluate the calibrated 28 nm power model
//!    on both floorplans.
//! 5. Print the Fig. 4 / Fig. 5 series and write `out/fig4_fig5.csv`.
//!
//! Run: `cargo run --release --example resnet50_power`
//! (falls back to the native im2col path if `artifacts/` is missing).

use asymm_sa::config::ExperimentConfig;
use asymm_sa::floorplan::{PeGeometry, WireTiming};
use asymm_sa::report;
use asymm_sa::runtime::Runtime;
use asymm_sa::workloads::table1_layers;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = ExperimentConfig::paper();
    // Derive the aspect ratio from *measured* activities (the paper's
    // §III-B procedure) instead of pinning 3.8.
    cfg.floorplans.proposed_aspect = None;

    let runtime = match Runtime::load("artifacts") {
        Ok(rt) => {
            println!(
                "PJRT {} | {} layer artifacts | activity oracle {}x{}",
                rt.platform(),
                rt.manifest().layers.len(),
                rt.manifest().activity.cycles,
                rt.manifest().activity.lanes,
            );
            Some(rt)
        }
        Err(e) => {
            eprintln!("note: running without PJRT runtime ({e})");
            None
        }
    };

    let layers = table1_layers();
    let t0 = std::time::Instant::now();
    let out = report::run_experiment(&cfg, &layers, runtime.as_ref())?;
    let wall = t0.elapsed().as_secs_f64();

    let mut rows = out.rows.clone();
    rows.push(out.average.clone());

    println!();
    println!(
        "measured average activities: a_h={:.3} a_v={:.3}  (paper: 0.22 / 0.36)",
        out.avg_activities.0, out.avg_activities.1
    );
    println!(
        "eq.6 aspect ratio from measurements: W/H = {:.3}  (paper: 3.8)",
        out.aspect_used
    );
    println!();
    print!("{}", report::fig4_string(&rows));
    println!();
    print!("{}", report::fig5_string(&rows));
    println!();
    println!(
        "headline: interconnect saving {:.1}% (paper: 9.1%), total saving {:.2}% (paper: 2.1%)",
        100.0 * out.average.interconnect_reduction(),
        100.0 * out.average.total_reduction(),
    );
    println!(
        "pipeline: {} layers in {wall:.1}s wall | {:.1}M MACs | {:.2}e9 simulated PE-cycles/s | runtime={}",
        out.rows.len(),
        out.metrics.macs as f64 / 1e6,
        out.metrics.pe_cycles_per_sec(cfg.sa.num_pes()) / 1e9,
        out.used_runtime,
    );

    std::fs::create_dir_all("out")?;
    std::fs::write("out/fig4_fig5.csv", report::to_csv(&rows))?;
    println!("wrote out/fig4_fig5.csv");

    // Zero-performance-cost check (paper SSIV): both floorplans meet the
    // 1 GHz clock under the Elmore wire model.
    let timing = WireTiming::default();
    let area = cfg.pe_area_um2();
    for (label, aspect) in [("square", 1.0), ("asymmetric", out.aspect_used)] {
        let pe = PeGeometry::new(area, aspect)?;
        let fmax = timing.max_clock_ghz(&pe);
        println!(
            "timing({label}, W/H={aspect:.2}): max bus clock {fmax:.1} GHz (target {} GHz) — {}",
            cfg.sa.clock_ghz,
            if timing.meets_timing(&cfg.sa, &pe) { "OK" } else { "FAIL" }
        );
        assert!(timing.meets_timing(&cfg.sa, &pe), "zero performance cost violated");
    }

    // Shape checks (the reproduction contract).
    assert!(out.avg_activities.1 > out.avg_activities.0, "a_v > a_h");
    assert!(out.aspect_used > 1.0, "asymmetric PEs are wider than tall");
    for r in &rows {
        assert!(
            r.interconnect_reduction() > 0.0,
            "asymmetric must win on every layer ({})",
            r.name
        );
    }
    println!("resnet50_power OK");
    Ok(())
}
