//! Serving demo: thin client of the first-class serve subsystem.
//!
//! Everything the old hand-rolled loop did — request generation,
//! batching, dispatch, latency accounting — now lives in
//! `asymm_sa::serve` (shape-coalesced batching in front of the
//! coordinator + a memoized result cache). This example just configures
//! a [`Server`], streams a seeded scenario through it, and prints the
//! summary. The `repro serve` subcommand drives an equivalent
//! (differently-seeded, flag-configurable) scenario through the same
//! API and additionally writes a JSON summary.
//!
//! Run: `cargo run --release --example serve_demo`

use asymm_sa::arch::SaConfig;
use asymm_sa::serve::{run_scenario, session::serving_mix, ScenarioConfig, ServeConfig, Server};
use asymm_sa::sim::engine::DataflowKind;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let sa = SaConfig::paper_32x32();
    let server = Server::new(ServeConfig {
        sa: sa.clone(),
        workers: 0,
        cache_capacity: 24,
        window: 16,
        engine: DataflowKind::Ws,
    });
    println!(
        "serve_demo: 32x32 WS array, {} workers, window {}, cache {} entries",
        server.coordinator().workers(),
        server.config().window,
        server.config().cache_capacity,
    );
    // The pool splits the machine between layer fan-out and intra-GEMM
    // column sharding per coalesced batch; show what this host does for
    // a full admission window.
    let (layer_workers, intra) = server.coordinator().negotiate(server.config().window);
    println!(
        "parallelism negotiation for a full window: {layer_workers} layer workers x {intra} intra threads"
    );

    let scenario = ScenarioConfig {
        seed: 1,
        requests: 48,
        unique_inputs: 4,
    };
    let (responses, sum) = run_scenario(&server, &scenario, &serving_mix())?;
    println!("{sum}");

    // Silicon-side stats: what the modeled accelerator would have done
    // for every served response (cached ones included — that is the
    // point of the cache).
    let silicon_s: f64 = responses.iter().map(|r| r.sim.silicon_seconds(&sa)).sum();
    println!(
        "modeled silicon time at {:.1} GHz: {:.3} ms total across served responses \
         ({:.0}x faster than the serving wall clock)",
        sa.clock_ghz,
        silicon_s * 1e3,
        sum.wall_secs / silicon_s.max(1e-12)
    );
    let snap = server.metrics().snapshot();
    println!(
        "metrics: {} sim jobs, {:.2}e9 PE-cycles/s simulated, cache hit rate {:.1}%",
        snap.jobs,
        snap.pe_cycles_per_sec(sa.num_pes()) / 1e9,
        100.0 * snap.cache_hit_rate()
    );
    assert!(
        snap.cache_hits > 0,
        "seeded scenario must produce repeat traffic"
    );
    println!("serve_demo OK");
    Ok(())
}
