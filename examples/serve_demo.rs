//! Serving demo: the coordinator as a request-driven accelerator service.
//!
//! Simulates a stream of conv-layer inference requests arriving at a
//! configurable rate, dispatches them through the thread-pool coordinator
//! (bounded queue = backpressure), and reports latency percentiles and
//! throughput — the operational view of the L3 layer that the figure
//! harness uses in batch mode.
//!
//! Run: `cargo run --release --example serve_demo`

use std::sync::Arc;
use std::time::Instant;

use asymm_sa::arch::SaConfig;
use asymm_sa::coordinator::{Coordinator, LayerJob};
use asymm_sa::gemm::{im2col, Matrix};
use asymm_sa::quant::quantize_sym;
use asymm_sa::workloads::{ActivationModel, ConvLayer, SynthGen};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let sa = SaConfig::paper_32x32();
    let coord = Coordinator::new(&sa, 0);
    println!(
        "serve_demo: 32x32 WS array, {} workers, bounded queue {}",
        coord.workers(),
        coord.workers() * 2
    );
    // The pool splits the machine between layer fan-out and intra-GEMM
    // column sharding per batch; show what this host negotiates.
    let (layer_workers, intra) = coord.negotiate(24);
    println!(
        "parallelism negotiation for 24 requests: {layer_workers} layer workers x {intra} intra threads"
    );

    // Request mix: small conv layers of three sizes (edge-inference-ish).
    let mk = |name: &str, k, hw, c, m| ConvLayer {
        name: name.into(),
        k,
        h: hw,
        w: hw,
        c,
        m,
        stride: 1,
    };
    let mix = [
        mk("tiny-1x1", 1, 14, 64, 64),
        mk("mid-3x3", 3, 14, 32, 64),
        mk("wide-1x1", 1, 28, 128, 64),
    ];

    // Materialize a batch of requests round-robin over the mix.
    let n_requests = 24;
    let mut gen = SynthGen::new(1);
    let model = ActivationModel::default();
    let mut jobs = Vec::new();
    for i in 0..n_requests {
        let layer = &mix[i % mix.len()];
        let (hin, win) = layer.input_hw();
        let x = gen.activations(layer.c, hin, win, &model);
        let ck2 = layer.c * layer.k * layer.k;
        let w = gen.weights(layer.m, ck2);
        let patches = im2col(&x, layer.c, hin, win, layer.k, layer.stride, layer.pad())?;
        let aq = quantize_sym(&patches.data, 16);
        let wq = quantize_sym(&w, 16);
        let w_mat = Matrix::from_vec(layer.m, ck2, wq.values)?.transpose();
        jobs.push(LayerJob {
            name: format!("req{:02}:{}", i, layer.name),
            a: Arc::new(Matrix::from_vec(patches.rows, patches.cols, aq.values)?),
            w: Arc::new(w_mat),
        });
    }

    let t0 = Instant::now();
    let results = coord.run(jobs)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut lat: Vec<f64> = results.iter().map(|r| r.wall_secs * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let pct = |p: f64| lat[((p * (lat.len() - 1) as f64).round()) as usize];
    let total_macs: u64 = results.iter().map(|r| r.sim.macs).sum();

    println!(
        "{} requests in {:.2}s -> {:.1} req/s, {:.2} GMAC/s simulated",
        results.len(),
        wall,
        results.len() as f64 / wall,
        total_macs as f64 / wall / 1e9
    );
    println!(
        "per-request sim latency: p50 {:.1} ms, p90 {:.1} ms, p99 {:.1} ms, max {:.1} ms",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        pct(1.0)
    );

    // Silicon-side stats: what the modeled accelerator would have done.
    let silicon_s: f64 = results.iter().map(|r| r.sim.silicon_seconds(&sa)).sum();
    println!(
        "modeled silicon time at {:.1} GHz: {:.3} ms total ({:.0}x faster than simulation)",
        sa.clock_ghz,
        silicon_s * 1e3,
        wall / silicon_s
    );
    let snap = coord.metrics().snapshot();
    println!(
        "metrics: {} jobs, {:.2}e9 PE-cycles/s simulated",
        snap.jobs,
        snap.pe_cycles_per_sec(sa.num_pes()) / 1e9
    );
    println!("serve_demo OK");
    Ok(())
}
