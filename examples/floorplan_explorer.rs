//! Floorplan explorer — regenerates the paper's Fig. 3 and the aspect
//! sweep behind eq. 6.
//!
//! Emits `out/fig3_symmetric.svg` and `out/fig3_asymmetric.svg` (the 8×8
//! layouts the paper plots), prints ASCII versions, and sweeps the aspect
//! ratio over the full interconnect-power model to show the bowl whose
//! minimum the closed form predicts (plus where the ctrl/clock term moves
//! it). Also writes `out/aspect_sweep.csv`.
//!
//! Run: `cargo run --release --example floorplan_explorer`

use std::fmt::Write as _;

use asymm_sa::arch::SaConfig;
use asymm_sa::config::ExperimentConfig;
use asymm_sa::floorplan::{optimizer, svg, ArrayLayout, PeGeometry};
use asymm_sa::power::{self, TechParams};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    std::fs::create_dir_all("out")?;
    let cfg = ExperimentConfig::paper();
    let area = cfg.pe_area_um2();
    println!("PE area model: A = {area:.0} um^2 (28nm gate-count estimate)\n");

    // --- Fig. 3: 8x8 layouts, square vs 3.8 --------------------------------
    let sa8 = SaConfig::paper_8x8();
    for (name, aspect) in [("fig3_symmetric", 1.0), ("fig3_asymmetric", 3.8)] {
        let layout = ArrayLayout::generate(&sa8, PeGeometry::new(area, aspect)?)?;
        println!("{}", svg::render_ascii(&layout));
        let (w, h) = layout.extent_um();
        println!(
            "outline {w:.0} x {h:.0} um, total bus wirelength {:.1} mm\n",
            layout.total_wirelength_um() / 1000.0
        );
        std::fs::write(
            format!("out/{name}.svg"),
            svg::render_svg(&layout, name),
        )?;
    }

    // --- Aspect sweep over the full interconnect model ---------------------
    let sa = SaConfig::paper_32x32();
    let tech = TechParams::default();
    let (a_h, a_v) = (0.22, 0.36);
    let pts = optimizer::sweep_ratio(
        |r| power::model_interconnect_cost(&sa, &tech, a_h, a_v, area, r),
        0.25,
        16.0,
        41,
    );
    let base = power::model_interconnect_cost(&sa, &tech, a_h, a_v, area, 1.0);
    let bus_only = optimizer::closed_form_ratio(&sa, a_h, a_v);
    let (full_opt, _) = optimizer::minimize_ratio(
        |r| power::model_interconnect_cost(&sa, &tech, a_h, a_v, area, r),
        0.2,
        20.0,
        1e-9,
    );

    println!("aspect sweep (32x32, a_h={a_h}, a_v={a_v}):");
    println!("{:>8} {:>14} {:>9}", "W/H", "fJ/PE-cycle", "vs sq");
    let mut csv = String::from("aspect,cost_fj,vs_square\n");
    for &(r, c) in &pts {
        let rel = 100.0 * (c / base - 1.0);
        println!("{r:>8.3} {c:>14.4} {rel:>8.1}%");
        let _ = writeln!(csv, "{r:.6},{c:.6},{:.6}", c / base - 1.0);
    }
    std::fs::write("out/aspect_sweep.csv", csv)?;
    println!();
    println!("bus-only optimum (eq.6):     W/H = {bus_only:.3}");
    println!("full-model optimum (w/ctrl): W/H = {full_opt:.3}");
    println!("wrote out/fig3_symmetric.svg, out/fig3_asymmetric.svg, out/aspect_sweep.csv");
    Ok(())
}
