//! Quickstart: the paper's result in 60 seconds.
//!
//! 1. Build the paper's 32×32 int16 WS array config (B_v derives to 37).
//! 2. Compute the optimal PE aspect ratio (eqs. 5/6) → ≈3.8.
//! 3. Simulate a small quantized GEMM on both engines (cycle-accurate and
//!    analytic) and show they agree bit-exactly.
//! 4. Evaluate interconnect power on square vs asymmetric floorplans.
//! 5. If `artifacts/` exists, run one 32×32 tile product through the
//!    AOT-compiled Pallas kernel via PJRT and check it against the
//!    native reference.
//!
//! Run: `cargo run --release --example quickstart`

use asymm_sa::arch::SaConfig;
use asymm_sa::config::ExperimentConfig;
use asymm_sa::floorplan::{optimizer, PeGeometry};
use asymm_sa::gemm::Matrix;
use asymm_sa::power::{self, TechParams};
use asymm_sa::runtime::Runtime;
use asymm_sa::sim::{fast::simulate_gemm_fast, ws::WsCycleSim};
use asymm_sa::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- 1. the paper's array --------------------------------------------
    let sa = SaConfig::paper_32x32();
    println!(
        "array: {}x{} WS, B_h={} bits, B_v={} bits (derived lossless)",
        sa.rows,
        sa.cols,
        sa.bus_bits_horizontal(),
        sa.bus_bits_vertical()
    );

    // --- 2. optimal aspect ratio -----------------------------------------
    let (a_h, a_v) = (0.22, 0.36); // the paper's measured averages
    println!(
        "eq.5  W/H = B_v/B_h                = {:.3}",
        optimizer::wirelength_optimal_ratio(&sa)
    );
    let r_star = optimizer::closed_form_ratio(&sa, a_h, a_v);
    println!("eq.6  W/H = (B_v a_v)/(B_h a_h)    = {r_star:.3}  <- the paper's 3.8");

    // --- 3. simulate a quantized GEMM on both engines ---------------------
    let mut rng = Rng::new(42);
    let a = Matrix::from_vec(
        96,
        64,
        (0..96 * 64)
            .map(|_| if rng.chance(0.5) { 0 } else { rng.int_range(0, 2000) as i32 })
            .collect(),
    )?;
    let w = Matrix::from_vec(
        64,
        48,
        (0..64 * 48).map(|_| rng.int_range(-2000, 2000) as i32).collect(),
    )?;
    let cyc = WsCycleSim::new(&sa).simulate_gemm(&a, &w)?;
    let fast = simulate_gemm_fast(&sa, &a, &w)?;
    assert_eq!(cyc.y, fast.y);
    assert_eq!(cyc.stats, fast.stats);
    let (mh, mv) = fast.stats.activities();
    println!(
        "sim: 96x64x48 GEMM, {} cycles, measured a_h={mh:.3} a_v={mv:.3} (a_v > a_h as SSII predicts)",
        fast.cycles
    );

    // --- 4. power on both floorplans --------------------------------------
    let cfg = ExperimentConfig::paper();
    let area = cfg.pe_area_um2();
    let tech = TechParams::default();
    let sym = power::evaluate(&sa, &PeGeometry::square(area)?, &tech, &fast);
    let asym = power::evaluate(&sa, &PeGeometry::new(area, r_star)?, &tech, &fast);
    println!(
        "interconnect: square {:.2} mW -> asymmetric {:.2} mW  ({:.1}% saving)",
        sym.interconnect_mw(),
        asym.interconnect_mw(),
        100.0 * (1.0 - asym.interconnect_mw() / sym.interconnect_mw())
    );
    println!(
        "total:        square {:.2} mW -> asymmetric {:.2} mW  ({:.2}% saving)",
        sym.total_mw(),
        asym.total_mw(),
        100.0 * (1.0 - asym.total_mw() / sym.total_mw())
    );

    // --- 5. PJRT round trip through the Pallas kernel ---------------------
    match Runtime::load("artifacts") {
        Ok(rt) => {
            let t = rt.manifest().tile_matmul.tile;
            let mut rng = Rng::new(7);
            let af: Vec<f32> = (0..t * t).map(|_| rng.normal() as f32).collect();
            let wf: Vec<f32> = (0..t * t).map(|_| rng.normal() as f32).collect();
            let got = rt.tile_matmul(&af, &wf)?;
            let am = Matrix::from_vec(t, t, af.clone())?;
            let wm = Matrix::from_vec(t, t, wf.clone())?;
            let want = asymm_sa::gemm::matmul_f32(&am, &wm)?;
            let max_err = got
                .iter()
                .zip(want.data.iter())
                .map(|(g, w)| (g - w).abs())
                .fold(0f32, f32::max);
            println!(
                "PJRT: {t}x{t} tile product through the AOT Pallas WS kernel, max |err| = {max_err:.2e}"
            );
            assert!(max_err < 1e-3);
        }
        Err(e) => println!("PJRT step skipped ({e})"),
    }

    println!("quickstart OK");
    Ok(())
}
