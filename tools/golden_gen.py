#!/usr/bin/env python3
"""Generate rust/tests/golden/table1.json — the golden-vector fixture.

This is an exact, bit-for-bit port of the crate's frozen scalar analytic
engine (rust/src/sim/baseline.rs) plus the pieces the fixture depends on:

  * util::rng::Rng            (SplitMix64, pure integer)
  * the golden input scheme   (tests/golden_vectors.rs::golden_matrix)
  * quant::bus_word           (two's-complement masking)
  * the WS tile schedule      (gemm::tiling::TilePlan: n-major, k-minor)
  * serve::cache::digest_i64  (FNV-1a, length-prefixed, little-endian)
  * power::evaluate           (interconnect terms only, f64 arithmetic
                               replicated operation-for-operation)

Why a Python generator exists at all: the fixture must be produced by an
implementation *independent* of the engine under test (otherwise the
golden tier would bless whatever the engine says today), and the repo's
build containers do not always ship a Rust toolchain. The port is
differentially validated in two ways before writing anything:

  1. a line-by-line scalar transliteration of baseline.rs is compared
     against the vectorized NumPy engine on randomized small shapes
     (catches vectorization mistakes — the realistic error class);
  2. structural invariants the Rust property suites enforce
     (observation conservation closed forms, activity <= 1, outputs ==
     exact matmul) are asserted on every generated layer.

The engines themselves are tied together on the Rust side: fast ==
scalar == cycle-accurate, enforced by tests/fast_engine_property.rs and
tests/engines_equivalence.rs. UPDATE_GOLDEN=1 on the Rust test
regenerates the same file from the fast engine; the two paths must agree
exactly on every integer.

Usage: python3 tools/golden_gen.py [--check-only]
"""

from __future__ import annotations

import json
import math
import sys
from pathlib import Path

import numpy as np

MASK64 = (1 << 64) - 1
PHI = 0x9E37_79B9_7F4A_7C15

# ----------------------------------------------------------------------
# util::rng::Rng (SplitMix64)
# ----------------------------------------------------------------------


class Rng:
    """Port of rust/src/util/rng.rs::Rng."""

    def __init__(self, seed: int):
        self.state = (seed ^ PHI) & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + PHI) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK64
        return (z ^ (z >> 31)) & MASK64


def rng_stream(seed: int, n: int) -> np.ndarray:
    """Vectorized SplitMix64: draw `n` values of Rng(seed) at once.

    The state after k calls is (seed ^ PHI) + k*PHI mod 2^64, so the
    whole stream is a closed form over a counter.
    """
    init = (seed ^ PHI) & MASK64
    ks = np.arange(1, n + 1, dtype=np.uint64)
    state = (np.uint64(init) + ks * np.uint64(PHI))  # wraps mod 2^64
    z = state
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


# ----------------------------------------------------------------------
# Golden input scheme (tests/golden_vectors.rs)
# ----------------------------------------------------------------------

INPUT_SEED = 0xA5A5_2023
A_SPARSITY_PCT = 40


def golden_matrix(rows: int, cols: int, seed: int, sparsity_pct: int) -> np.ndarray:
    """Port of golden_vectors.rs::golden_matrix (row-major int32).

    Sequential draws: one u64 decides zero/nonzero; nonzero elements
    draw a second u64 for the value. Consumption is data-dependent, so
    we draw a (precomputed, vectorized) stream and walk it.
    """
    n = rows * cols
    stream = rng_stream(seed, 2 * n)  # upper bound: 2 draws per element
    out = np.zeros(n, dtype=np.int32)
    pos = 0
    sv = stream  # local alias
    for i in range(n):
        r = int(sv[pos])
        pos += 1
        if r % 100 < sparsity_pct:
            continue
        v = int(sv[pos]) % 65535 - 32767
        pos += 1
        out[i] = v
    return out.reshape(rows, cols)


# ----------------------------------------------------------------------
# Scalar transliteration of sim/baseline.rs (reference for the port)
# ----------------------------------------------------------------------


def bus_word(v: int, bits: int) -> int:
    return v & ((1 << bits) - 1)


def pass_cycles(R: int, C: int, m: int) -> int:
    # sim::pass_cycles = rows + (m + rows + cols + 2)
    return R + (m + R + C + 2)


def tile_steps(K: int, N: int, R: int, C: int):
    steps = []
    n0 = 0
    while n0 < N:
        n_len = min(C, N - n0)
        k0 = 0
        while k0 < K:
            k_len = min(R, K - k0)
            steps.append((k0, n0, k_len, n_len))
            k0 += R
        n0 += C
    return steps


def simulate_ws_scalar(R, C, bh, bv, A, W):
    """Direct line-by-line port of simulate_gemm_fast_scalar. Slow —
    used only to validate the vectorized engine on small shapes."""
    m, K = A.shape
    N = W.shape[1]
    pc = pass_cycles(R, C, m)
    y = [[0] * N for _ in range(m)]
    stats = {k: [0, 0, 0] for k in ("h", "v", "wl")}  # toggles, zeros, obs
    chain_prev = [[0] * C for _ in range(R)]
    a_t = A.T.tolist()
    Wl = W.tolist()

    for (k0, n0, k_len, n_len) in tile_steps(K, N, R, C):
        w_tile = [
            [
                Wl[k0 + r][n0 + c] if (r < k_len and c < n_len) else 0
                for c in range(C)
            ]
            for r in range(R)
        ]
        # Weight chain.
        for r in range(R):
            for c in range(C):
                p = bus_word(chain_prev[r][c], bh)
                tog = 0
                zer = 0
                for t in range(R):
                    v = chain_prev[r - 1 - t][c] if t < r else w_tile[R - 1 - (t - r)][c]
                    word = bus_word(v, bh)
                    tog += bin(p ^ word).count("1")
                    zer += word == 0
                    p = word
                stats["wl"][0] += tog
                stats["wl"][1] += zer
                stats["wl"][2] += R
        chain_prev = [row[:] for row in w_tile]
        # Horizontal.
        for r in range(R):
            tog = nz = 0
            if r < k_len:
                p = 0
                for v in a_t[k0 + r]:
                    word = bus_word(int(v), bh)
                    tog += bin(p ^ word).count("1")
                    nz += word != 0
                    p = word
                tog += bin(p).count("1")
            stats["h"][0] += tog * C
            stats["h"][1] += (pc - nz) * C
            stats["h"][2] += pc * C
        # Vertical (column at a time; stat math identical to the pairs).
        for c in range(n_len):
            prefix = [0] * m
            last_tog = last_nz = 0
            for r in range(k_len):
                w_rc = w_tile[r][c]
                arow = a_t[k0 + r]
                tog = nz = 0
                prev = 0
                for mi in range(m):
                    prefix[mi] += int(arow[mi]) * w_rc
                    word = bus_word(prefix[mi], bv)
                    tog += bin(prev ^ word).count("1")
                    nz += word != 0
                    prev = word
                tog += bin(prev).count("1")
                stats["v"][0] += tog
                stats["v"][1] += pc - nz
                last_tog, last_nz = tog, nz
            tail = R - k_len
            stats["v"][0] += tail * last_tog
            stats["v"][1] += tail * (pc - last_nz)
            stats["v"][2] += pc * R
            for mi in range(m):
                y[mi][n0 + c] += prefix[mi]
        if n_len < C:
            idle = C - n_len
            stats["v"][1] += idle * pc * R
            stats["v"][2] += idle * pc * R

    cycles = len(tile_steps(K, N, R, C)) * pc
    macs = m * K * N
    return np.array(y, dtype=np.int64), stats, cycles, macs


# ----------------------------------------------------------------------
# Vectorized NumPy engine (the production generator)
# ----------------------------------------------------------------------


def _u64(x: np.ndarray) -> np.ndarray:
    return x.astype(np.int64).view(np.uint64)


def _pc64(x: np.ndarray) -> np.ndarray:
    return np.bitwise_count(x).astype(np.int64)


def simulate_ws_numpy(R, C, bh, bv, A, W):
    """Vectorized port of simulate_gemm_fast_scalar."""
    m, K = A.shape
    N = W.shape[1]
    pc = pass_cycles(R, C, m)
    mask_h = np.uint64((1 << bh) - 1)
    mask_v = np.uint64((1 << bv) - 1)
    A64 = A.astype(np.int64)
    a_t = A64.T.copy()
    y = np.zeros((m, N), dtype=np.int64)
    h_tog = h_zer = h_obs = 0
    v_tog = v_zer = v_obs = 0
    wl_tog = wl_zer = wl_obs = 0
    chain_prev = np.zeros((R, C), dtype=np.int64)

    # Gather indices for the weight-chain sequence (constant per array).
    T, Rr = np.meshgrid(np.arange(R), np.arange(R), indexing="ij")
    from_prev = T < Rr
    idx_prev = np.clip(Rr - 1 - T, 0, R - 1)
    idx_new = np.clip(R - 1 - (T - Rr), 0, R - 1)

    steps = tile_steps(K, N, R, C)
    for (k0, n0, k_len, n_len) in steps:
        w_tile = np.zeros((R, C), dtype=np.int64)
        w_tile[:k_len, :n_len] = W[k0 : k0 + k_len, n0 : n0 + n_len]

        # ---- Weight chain ------------------------------------------------
        seq = np.where(from_prev[:, :, None], chain_prev[idx_prev], w_tile[idx_new])
        words = _u64(seq) & mask_h  # (T=R, r=R, c=C)
        p0 = (_u64(chain_prev) & mask_h)[None, :, :]
        prev = np.concatenate([p0, words[:-1]], axis=0)
        wl_tog += int(_pc64(prev ^ words).sum())
        wl_zer += int((words == 0).sum())
        wl_obs += R * R * C
        chain_prev = w_tile

        # ---- Horizontal --------------------------------------------------
        rows = a_t[k0 : k0 + k_len]  # (k_len, m)
        words = _u64(rows) & mask_h
        prev = np.concatenate(
            [np.zeros((k_len, 1), dtype=np.uint64), words[:, :-1]], axis=1
        )
        tog_r = _pc64(prev ^ words).sum(axis=1) + _pc64(words[:, -1])
        nz_r = (words != 0).sum(axis=1).astype(np.int64)
        h_tog += int(tog_r.sum()) * C
        h_zer += int((pc - nz_r).sum()) * C + (R - k_len) * pc * C
        h_obs += pc * C * R

        # ---- Vertical ----------------------------------------------------
        prod = a_t[k0 : k0 + k_len, :, None] * w_tile[:k_len, None, :n_len]
        prefix = np.cumsum(prod, axis=0)  # (k_len, m, n_len) exact int64
        words = _u64(prefix) & mask_v
        prev = np.concatenate(
            [np.zeros((k_len, 1, n_len), dtype=np.uint64), words[:, :-1, :]], axis=1
        )
        tog = _pc64(prev ^ words).sum(axis=1) + _pc64(words[:, -1, :])  # (k_len, n_len)
        nz = (words != 0).sum(axis=1).astype(np.int64)
        tail = R - k_len
        v_tog += int(tog.sum()) + tail * int(tog[-1].sum())
        v_zer += int((pc - nz).sum()) + tail * int((pc - nz[-1]).sum())
        v_obs += pc * R * n_len
        if n_len < C:
            v_zer += (C - n_len) * pc * R
            v_obs += (C - n_len) * pc * R
        y[:, n0 : n0 + n_len] += prefix[-1]

    stats = {
        "h": [h_tog, h_zer, h_obs],
        "v": [v_tog, v_zer, v_obs],
        "wl": [wl_tog, wl_zer, wl_obs],
    }
    return y, stats, len(steps) * pc, m * K * N


# ----------------------------------------------------------------------
# serve::cache::digest_i64 (FNV-1a, length-prefixed, LE)
# ----------------------------------------------------------------------

FNV_PRIME = 0x0000_0100_0000_01B3


def _fnv1a(h: int, data: bytes) -> int:
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & MASK64
    return h


def digest_i64(seed: int, values: np.ndarray) -> int:
    h = _fnv1a(seed, len(values).to_bytes(8, "little"))
    return _fnv1a(h, values.astype("<i8").tobytes())


# ----------------------------------------------------------------------
# power::evaluate — interconnect + compute terms, f64 op-for-op
# ----------------------------------------------------------------------

# TechParams::default()
VDD = 0.9
WIRE_CAP = 0.20
CTRL_EFF_WIRES = 2.514
MAC_ENERGY_FJ = 130.0
ZERO_GATING = 0.8
FF_ENERGY_FJ_PER_BIT = 0.7
LEAKAGE_UW_PER_PE = 20.0
# PeMicroArch::default().cost(paper_32x32): the paper's constant A.
NAND2_UM2 = 0.49
UTILIZATION = 0.70


def pe_area_um2(bh: int, bv: int) -> float:
    register_bits = 2 * bh + bv
    mult_gates = 1.1 * float(bh) * float(bh)
    add_gates = 6.0 * float(bv)
    ff_gates = 4.0 * float(register_bits)
    gates = mult_gates + add_gates + ff_gates
    return gates * NAND2_UM2 / UTILIZATION


def interconnect_mw(stats, cycles, R, C, area, aspect, clock_ghz=1.0):
    w_um = math.sqrt(area * aspect)
    h_um = math.sqrt(area / aspect)
    e_wire = 0.5 * WIRE_CAP * VDD * VDD
    seconds = float(cycles) / (clock_ghz * 1e9)
    h_bus_fj = float(stats["h"][0]) * w_um * e_wire
    v_bus_fj = float(stats["v"][0]) * h_um * e_wire
    w_load_fj = float(stats["wl"][0]) * h_um * e_wire
    ctrl_fj = float(cycles) * float(R * C) * CTRL_EFF_WIRES * (w_um + h_um) * e_wire

    def to_mw(fj: float) -> float:
        return fj * 1e-15 / seconds * 1e3

    return to_mw(h_bus_fj) + to_mw(v_bus_fj) + to_mw(w_load_fj) + to_mw(ctrl_fj)


def compute_mw(stats, cycles, macs, R, C, bh, bv, clock_ghz=1.0):
    """power::evaluate's PE-internal terms (mac + reg + leak), replicated
    operation-for-operation: floorplan-invariant, so one value covers both
    geometries (the Rust generator asserts the same invariance)."""
    seconds = float(cycles) / (clock_ghz * 1e9)

    def to_mw(fj: float) -> float:
        return fj * 1e-15 / seconds * 1e3

    # Multiplier data gating over the horizontal zero fraction.
    zero_frac = float(stats["h"][1]) / float(stats["h"][2])
    scale = float(bh) / 16.0
    mac_eff_fj = (MAC_ENERGY_FJ * scale * scale) * (1.0 - ZERO_GATING * zero_frac)
    mac_fj = float(macs) * mac_eff_fj

    register_bits = 2 * bh + bv
    reg_fj = float(cycles) * float(R * C) * float(register_bits) * FF_ENERGY_FJ_PER_BIT

    leak_mw = LEAKAGE_UW_PER_PE * float(R * C) * 1e-3
    return to_mw(mac_fj) + to_mw(reg_fj) + leak_mw


# ----------------------------------------------------------------------
# Validation + generation
# ----------------------------------------------------------------------

TABLE1 = [
    # name, (P, CK^2, M) — workloads::gemm_shape over table1_layers()
    ("L1", (3136, 256, 64)),
    ("L2", (784, 1152, 128)),
    ("L3", (784, 128, 512)),
    ("L4", (196, 512, 256)),
    ("L5", (196, 1024, 256)),
    ("L6", (196, 2304, 256)),
]


def selfcheck():
    """Differential: scalar transliteration == vectorized engine."""
    rng = Rng(99)
    cases = [
        (4, 4, 8, 6, 4, 4),
        (4, 4, 8, 7, 10, 9),
        (8, 4, 8, 5, 8, 4),
        (5, 3, 12, 9, 11, 7),
        (4, 4, 16, 13, 33, 40),  # ragged multi-pass at full width
        (4, 4, 8, 1, 1, 1),
    ]
    for (R, C, bits, m, k, n) in cases:
        hi = (1 << (bits - 1)) - 1
        bv = 2 * bits + max(0, (R - 1).bit_length()) if R > 1 else 2 * bits
        A = np.array(
            [rng.next_u64() % (2 * hi + 1) - hi for _ in range(m * k)], dtype=np.int64
        ).reshape(m, k)
        W = np.array(
            [rng.next_u64() % (2 * hi + 1) - hi for _ in range(k * n)], dtype=np.int64
        ).reshape(k, n)
        ys, ss, cs, ms = simulate_ws_scalar(R, C, bits, bv, A, W)
        yv, sv, cv, mv = simulate_ws_numpy(R, C, bits, bv, A, W)
        assert np.array_equal(ys, yv), f"y mismatch {R}x{C} {m}x{k}x{n}"
        assert ss == sv, f"stats mismatch {R}x{C} {m}x{k}x{n}: {ss} vs {sv}"
        assert (cs, ms) == (cv, mv)
        assert np.array_equal(yv, A @ W), "outputs must equal exact matmul"
        # Observation conservation closed forms (mirrors the Rust
        # property suite).
        passes = math.ceil(k / R) * math.ceil(n / C)
        pc = pass_cycles(R, C, m)
        assert sv["h"][2] == passes * pc * R * C
        assert sv["v"][2] == passes * pc * R * C
        assert sv["wl"][2] == passes * R * R * C
        for key, bits_k in (("h", bits), ("v", bv), ("wl", bits)):
            tog, zer, obs = sv[key]
            assert 0 <= zer <= obs and 0 <= tog <= obs * bits_k
    # RNG sanity: scalar class and closed-form stream agree.
    r = Rng(12345)
    seq = [r.next_u64() for _ in range(100)]
    assert seq == [int(x) for x in rng_stream(12345, 100)]
    print("selfcheck: scalar == vectorized on all cases, invariants hold")


def compute_doc() -> dict:
    R, C, BH, BV = 32, 32, 16, 37
    area = pe_area_um2(BH, BV)
    layers = []
    for idx, (name, (m, k, n)) in enumerate(TABLE1):
        A = golden_matrix(m, k, INPUT_SEED + 1000 + idx, A_SPARSITY_PCT)
        W = golden_matrix(k, n, INPUT_SEED + 2000 + idx, 0)
        y, stats, cycles, macs = simulate_ws_numpy(R, C, BH, BV, A, W)
        assert np.array_equal(y, A.astype(np.int64) @ W.astype(np.int64))
        passes = math.ceil(k / R) * math.ceil(n / C)
        pc = pass_cycles(R, C, m)
        assert cycles == passes * pc and macs == m * k * n
        assert stats["h"][2] == passes * pc * R * C
        assert stats["v"][2] == passes * pc * R * C
        assert stats["wl"][2] == passes * R * R * C
        a_act = stats["h"][0] / (stats["h"][2] * BH)
        v_act = stats["v"][0] / (stats["v"][2] * BV)
        assert 0.0 < a_act <= 1.0 and 0.0 < v_act <= 1.0
        ic_sym = interconnect_mw(stats, cycles, R, C, area, 1.0)
        ic_asym = interconnect_mw(stats, cycles, R, C, area, 3.8)
        comp = compute_mw(stats, cycles, macs, R, C, BH, BV)
        entry = {
            "name": name,
            "gemm": [m, k, n],
            "horizontal": dict(
                zip(("toggles", "zero_words", "observations"), stats["h"])
            ),
            "vertical": dict(zip(("toggles", "zero_words", "observations"), stats["v"])),
            "weight_load": dict(
                zip(("toggles", "zero_words", "observations"), stats["wl"])
            ),
            "cycles": cycles,
            "macs": macs,
            "y_digest": format(digest_i64(0, y.reshape(-1)), "016x"),
            "interconnect_sym_mw": ic_sym,
            "interconnect_asym_mw": ic_asym,
            "compute_mw": comp,
            "total_sym_mw": ic_sym + comp,
            "total_asym_mw": ic_asym + comp,
        }
        layers.append(entry)
        print(
            f"{name}: {m}x{k}x{n}  a_h={a_act:.3f} a_v={v_act:.3f} "
            f"cycles={cycles} icn_sym={entry['interconnect_sym_mw']:.3f}mW "
            f"total_sym={entry['total_sym_mw']:.3f}mW"
        )
    return {
        "description": (
            "Golden bus statistics for the Table-I layers on the paper's 32x32 "
            "WS array. Regenerate with UPDATE_GOLDEN=1 cargo test --test "
            "golden_vectors."
        ),
        "sa": {"rows": R, "cols": C, "input_bits": BH, "acc_bits": BV},
        "input_seed": INPUT_SEED,
        "a_sparsity_pct": A_SPARSITY_PCT,
        "layers": layers,
    }


def compare_against(path: Path, doc: dict) -> None:
    """Value-wise comparison with the checked-in fixture: integers exact,
    floats to 1e-9 relative (the same contract golden_vectors.rs
    enforces). Exits nonzero on any disagreement, so `--check-only`
    really does arbitrate between the Rust UPDATE_GOLDEN=1 writer and
    this independent port."""
    golden = json.loads(path.read_text())
    diffs = []

    def walk(prefix, want, have):
        if isinstance(want, dict) and isinstance(have, dict):
            for key in sorted(set(want) | set(have)):
                if key not in want or key not in have:
                    diffs.append(f"{prefix}.{key}: present on one side only")
                else:
                    walk(f"{prefix}.{key}", want[key], have[key])
        elif isinstance(want, list) and isinstance(have, list):
            if len(want) != len(have):
                diffs.append(f"{prefix}: length {len(want)} vs {len(have)}")
            for i, (w, h) in enumerate(zip(want, have)):
                walk(f"{prefix}[{i}]", w, h)
        elif isinstance(want, float) or isinstance(have, float):
            if abs(want - have) > 1e-9 * max(abs(want), 1e-300):
                diffs.append(f"{prefix}: {want} vs {have}")
        elif want != have:
            diffs.append(f"{prefix}: {want!r} vs {have!r}")

    walk("fixture", golden, doc)
    if diffs:
        print(f"FIXTURE DISAGREEMENT ({len(diffs)} fields):")
        for d in diffs[:40]:
            print(" ", d)
        sys.exit(1)
    print(f"{path}: checked-in fixture matches this generator value-for-value")


if __name__ == "__main__":
    selfcheck()
    fixture = Path(__file__).resolve().parent.parent / "rust/tests/golden/table1.json"
    doc = compute_doc()
    if "--check-only" in sys.argv:
        compare_against(fixture, doc)
    else:
        fixture.parent.mkdir(parents=True, exist_ok=True)
        fixture.write_text(json.dumps(doc, sort_keys=True, separators=(",", ":")))
        print(f"wrote {fixture}")
