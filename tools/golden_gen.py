#!/usr/bin/env python3
"""Generate the golden-vector fixtures:

  * rust/tests/golden/table1.json     — WS engine (Table-I layers)
  * rust/tests/golden/dataflows.json  — OS and IS engines, same layers

This is an exact, bit-for-bit port of the crate's frozen scalar analytic
engines (rust/src/sim/baseline.rs — WS, OS and IS) plus the pieces the
fixtures depend on:

  * util::rng::Rng            (SplitMix64, pure integer)
  * the golden input scheme   (tests/golden_vectors.rs::golden_matrix)
  * quant::bus_word           (two's-complement masking)
  * the WS tile schedule      (gemm::tiling::TilePlan: n-major, k-minor)
  * serve::cache::digest_i64  (FNV-1a, length-prefixed, little-endian)
  * power::evaluate           (interconnect terms only, f64 arithmetic
                               replicated operation-for-operation)

For each dataflow, two independent Python implementations are compared
before anything is written: a line-by-line scalar transliteration of the
frozen Rust engine, and a vectorized/closed-form port that mirrors the
blocked Rust engines' algebra (memoized streams, drain/preload closed
forms, pass-through tail scaling). Their agreement validates exactly the
identities the fast Rust engines rely on.

Why a Python generator exists at all: the fixture must be produced by an
implementation *independent* of the engine under test (otherwise the
golden tier would bless whatever the engine says today), and the repo's
build containers do not always ship a Rust toolchain. The port is
differentially validated in two ways before writing anything:

  1. a line-by-line scalar transliteration of baseline.rs is compared
     against the vectorized NumPy engine on randomized small shapes
     (catches vectorization mistakes — the realistic error class);
  2. structural invariants the Rust property suites enforce
     (observation conservation closed forms, activity <= 1, outputs ==
     exact matmul) are asserted on every generated layer.

The engines themselves are tied together on the Rust side: fast ==
scalar == cycle-accurate, enforced by tests/fast_engine_property.rs and
tests/engines_equivalence.rs. UPDATE_GOLDEN=1 on the Rust test
regenerates the same file from the fast engine; the two paths must agree
exactly on every integer.

Usage: python3 tools/golden_gen.py [--check-only]
"""

from __future__ import annotations

import json
import math
import sys
from pathlib import Path

import numpy as np

MASK64 = (1 << 64) - 1
PHI = 0x9E37_79B9_7F4A_7C15

# ----------------------------------------------------------------------
# util::rng::Rng (SplitMix64)
# ----------------------------------------------------------------------


class Rng:
    """Port of rust/src/util/rng.rs::Rng."""

    def __init__(self, seed: int):
        self.state = (seed ^ PHI) & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + PHI) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK64
        return (z ^ (z >> 31)) & MASK64


def rng_stream(seed: int, n: int) -> np.ndarray:
    """Vectorized SplitMix64: draw `n` values of Rng(seed) at once.

    The state after k calls is (seed ^ PHI) + k*PHI mod 2^64, so the
    whole stream is a closed form over a counter.
    """
    init = (seed ^ PHI) & MASK64
    ks = np.arange(1, n + 1, dtype=np.uint64)
    state = (np.uint64(init) + ks * np.uint64(PHI))  # wraps mod 2^64
    z = state
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


# ----------------------------------------------------------------------
# Golden input scheme (tests/golden_vectors.rs)
# ----------------------------------------------------------------------

INPUT_SEED = 0xA5A5_2023
A_SPARSITY_PCT = 40


def golden_matrix(rows: int, cols: int, seed: int, sparsity_pct: int) -> np.ndarray:
    """Port of golden_vectors.rs::golden_matrix (row-major int32).

    Sequential draws: one u64 decides zero/nonzero; nonzero elements
    draw a second u64 for the value. Consumption is data-dependent, so
    we draw a (precomputed, vectorized) stream and walk it.
    """
    n = rows * cols
    stream = rng_stream(seed, 2 * n)  # upper bound: 2 draws per element
    out = np.zeros(n, dtype=np.int32)
    pos = 0
    sv = stream  # local alias
    for i in range(n):
        r = int(sv[pos])
        pos += 1
        if r % 100 < sparsity_pct:
            continue
        v = int(sv[pos]) % 65535 - 32767
        pos += 1
        out[i] = v
    return out.reshape(rows, cols)


# ----------------------------------------------------------------------
# Scalar transliteration of sim/baseline.rs (reference for the port)
# ----------------------------------------------------------------------


def bus_word(v: int, bits: int) -> int:
    return v & ((1 << bits) - 1)


def pass_cycles(R: int, C: int, m: int) -> int:
    # sim::pass_cycles = rows + (m + rows + cols + 2)
    return R + (m + R + C + 2)


def tile_steps(K: int, N: int, R: int, C: int):
    steps = []
    n0 = 0
    while n0 < N:
        n_len = min(C, N - n0)
        k0 = 0
        while k0 < K:
            k_len = min(R, K - k0)
            steps.append((k0, n0, k_len, n_len))
            k0 += R
        n0 += C
    return steps


def simulate_ws_scalar(R, C, bh, bv, A, W):
    """Direct line-by-line port of simulate_gemm_fast_scalar. Slow —
    used only to validate the vectorized engine on small shapes."""
    m, K = A.shape
    N = W.shape[1]
    pc = pass_cycles(R, C, m)
    y = [[0] * N for _ in range(m)]
    stats = {k: [0, 0, 0] for k in ("h", "v", "wl")}  # toggles, zeros, obs
    chain_prev = [[0] * C for _ in range(R)]
    a_t = A.T.tolist()
    Wl = W.tolist()

    for (k0, n0, k_len, n_len) in tile_steps(K, N, R, C):
        w_tile = [
            [
                Wl[k0 + r][n0 + c] if (r < k_len and c < n_len) else 0
                for c in range(C)
            ]
            for r in range(R)
        ]
        # Weight chain.
        for r in range(R):
            for c in range(C):
                p = bus_word(chain_prev[r][c], bh)
                tog = 0
                zer = 0
                for t in range(R):
                    v = chain_prev[r - 1 - t][c] if t < r else w_tile[R - 1 - (t - r)][c]
                    word = bus_word(v, bh)
                    tog += bin(p ^ word).count("1")
                    zer += word == 0
                    p = word
                stats["wl"][0] += tog
                stats["wl"][1] += zer
                stats["wl"][2] += R
        chain_prev = [row[:] for row in w_tile]
        # Horizontal.
        for r in range(R):
            tog = nz = 0
            if r < k_len:
                p = 0
                for v in a_t[k0 + r]:
                    word = bus_word(int(v), bh)
                    tog += bin(p ^ word).count("1")
                    nz += word != 0
                    p = word
                tog += bin(p).count("1")
            stats["h"][0] += tog * C
            stats["h"][1] += (pc - nz) * C
            stats["h"][2] += pc * C
        # Vertical (column at a time; stat math identical to the pairs).
        for c in range(n_len):
            prefix = [0] * m
            last_tog = last_nz = 0
            for r in range(k_len):
                w_rc = w_tile[r][c]
                arow = a_t[k0 + r]
                tog = nz = 0
                prev = 0
                for mi in range(m):
                    prefix[mi] += int(arow[mi]) * w_rc
                    word = bus_word(prefix[mi], bv)
                    tog += bin(prev ^ word).count("1")
                    nz += word != 0
                    prev = word
                tog += bin(prev).count("1")
                stats["v"][0] += tog
                stats["v"][1] += pc - nz
                last_tog, last_nz = tog, nz
            tail = R - k_len
            stats["v"][0] += tail * last_tog
            stats["v"][1] += tail * (pc - last_nz)
            stats["v"][2] += pc * R
            for mi in range(m):
                y[mi][n0 + c] += prefix[mi]
        if n_len < C:
            idle = C - n_len
            stats["v"][1] += idle * pc * R
            stats["v"][2] += idle * pc * R

    cycles = len(tile_steps(K, N, R, C)) * pc
    macs = m * K * N
    return np.array(y, dtype=np.int64), stats, cycles, macs


# ----------------------------------------------------------------------
# Vectorized NumPy engine (the production generator)
# ----------------------------------------------------------------------


def _u64(x: np.ndarray) -> np.ndarray:
    return x.astype(np.int64).view(np.uint64)


def _pc64(x: np.ndarray) -> np.ndarray:
    return np.bitwise_count(x).astype(np.int64)


def simulate_ws_numpy(R, C, bh, bv, A, W):
    """Vectorized port of simulate_gemm_fast_scalar."""
    m, K = A.shape
    N = W.shape[1]
    pc = pass_cycles(R, C, m)
    mask_h = np.uint64((1 << bh) - 1)
    mask_v = np.uint64((1 << bv) - 1)
    A64 = A.astype(np.int64)
    a_t = A64.T.copy()
    y = np.zeros((m, N), dtype=np.int64)
    h_tog = h_zer = h_obs = 0
    v_tog = v_zer = v_obs = 0
    wl_tog = wl_zer = wl_obs = 0
    chain_prev = np.zeros((R, C), dtype=np.int64)

    # Gather indices for the weight-chain sequence (constant per array).
    T, Rr = np.meshgrid(np.arange(R), np.arange(R), indexing="ij")
    from_prev = T < Rr
    idx_prev = np.clip(Rr - 1 - T, 0, R - 1)
    idx_new = np.clip(R - 1 - (T - Rr), 0, R - 1)

    steps = tile_steps(K, N, R, C)
    for (k0, n0, k_len, n_len) in steps:
        w_tile = np.zeros((R, C), dtype=np.int64)
        w_tile[:k_len, :n_len] = W[k0 : k0 + k_len, n0 : n0 + n_len]

        # ---- Weight chain ------------------------------------------------
        seq = np.where(from_prev[:, :, None], chain_prev[idx_prev], w_tile[idx_new])
        words = _u64(seq) & mask_h  # (T=R, r=R, c=C)
        p0 = (_u64(chain_prev) & mask_h)[None, :, :]
        prev = np.concatenate([p0, words[:-1]], axis=0)
        wl_tog += int(_pc64(prev ^ words).sum())
        wl_zer += int((words == 0).sum())
        wl_obs += R * R * C
        chain_prev = w_tile

        # ---- Horizontal --------------------------------------------------
        rows = a_t[k0 : k0 + k_len]  # (k_len, m)
        words = _u64(rows) & mask_h
        prev = np.concatenate(
            [np.zeros((k_len, 1), dtype=np.uint64), words[:, :-1]], axis=1
        )
        tog_r = _pc64(prev ^ words).sum(axis=1) + _pc64(words[:, -1])
        nz_r = (words != 0).sum(axis=1).astype(np.int64)
        h_tog += int(tog_r.sum()) * C
        h_zer += int((pc - nz_r).sum()) * C + (R - k_len) * pc * C
        h_obs += pc * C * R

        # ---- Vertical ----------------------------------------------------
        prod = a_t[k0 : k0 + k_len, :, None] * w_tile[:k_len, None, :n_len]
        prefix = np.cumsum(prod, axis=0)  # (k_len, m, n_len) exact int64
        words = _u64(prefix) & mask_v
        prev = np.concatenate(
            [np.zeros((k_len, 1, n_len), dtype=np.uint64), words[:, :-1, :]], axis=1
        )
        tog = _pc64(prev ^ words).sum(axis=1) + _pc64(words[:, -1, :])  # (k_len, n_len)
        nz = (words != 0).sum(axis=1).astype(np.int64)
        tail = R - k_len
        v_tog += int(tog.sum()) + tail * int(tog[-1].sum())
        v_zer += int((pc - nz).sum()) + tail * int((pc - nz[-1]).sum())
        v_obs += pc * R * n_len
        if n_len < C:
            v_zer += (C - n_len) * pc * R
            v_obs += (C - n_len) * pc * R
        y[:, n0 : n0 + n_len] += prefix[-1]

    stats = {
        "h": [h_tog, h_zer, h_obs],
        "v": [v_tog, v_zer, v_obs],
        "wl": [wl_tog, wl_zer, wl_obs],
    }
    return y, stats, len(steps) * pc, m * K * N


# ----------------------------------------------------------------------
# OS engine: scalar transliteration of baseline.rs::simulate_gemm_os_scalar
# ----------------------------------------------------------------------


def blocks(total: int, step: int):
    return [(s, min(step, total - s)) for s in range(0, total, step)]


def os_pass_cycles(R: int, k: int) -> int:
    return k + R + 1


def is_pass_cycles(R: int, C: int, n: int) -> int:
    return R + n + R + C + 2


def simulate_os_scalar(R, C, bh, bv, A, W):
    """Line-by-line port of simulate_gemm_os_scalar. Slow — used only to
    validate the vectorized OS engine on small shapes."""
    m, k = A.shape
    n = W.shape[1]
    pc = os_pass_cycles(R, k)
    y = A.astype(np.int64) @ W.astype(np.int64)
    stats = {key: [0, 0, 0] for key in ("h", "v", "wl")}
    cycles = macs = 0
    Al = A.tolist()
    Wl = W.tolist()
    Yl = y.tolist()
    m0 = 0
    while m0 < m:
        m_len = min(R, m - m0)
        n0 = 0
        while n0 < n:
            n_len = min(C, n - n0)
            # Horizontal: row r streams a[m0+r][0..k].
            for r in range(R):
                tog = nz = 0
                if r < m_len:
                    p = 0
                    for kk in range(k):
                        word = bus_word(Al[m0 + r][kk], bh)
                        tog += bin(p ^ word).count("1")
                        nz += word != 0
                        p = word
                    tog += bin(p).count("1")
                stats["h"][0] += tog * C
                stats["h"][1] += (pc - nz) * C
                stats["h"][2] += pc * C
            # Vertical weight stream: column c streams w[0..k][n0+c].
            for c in range(C):
                tog = nz = 0
                if c < n_len:
                    p = 0
                    for kk in range(k):
                        word = bus_word(Wl[kk][n0 + c], bh)
                        tog += bin(p ^ word).count("1")
                        nz += word != 0
                        p = word
                    tog += bin(p).count("1")
                stats["wl"][0] += tog * R
                stats["wl"][1] += (pc - nz) * R
                stats["wl"][2] += pc * R
            # Output drain.
            for c in range(C):
                for r in range(R):
                    tog = nz = 0
                    if c < n_len:
                        p = 0
                        for rr in range(min(r, m_len - 1), -1, -1):
                            if r < m_len:
                                word = bus_word(Yl[m0 + rr][n0 + c], bv)
                                tog += bin(p ^ word).count("1")
                                nz += word != 0
                                p = word
                        tog += bin(p).count("1")
                    stats["v"][0] += tog
                    stats["v"][1] += pc - nz
                    stats["v"][2] += pc
            cycles += pc
            macs += m_len * k * n_len
            n0 += C
        m0 += R
    return y, stats, cycles, macs


# ----------------------------------------------------------------------
# OS engine: vectorized port of the blocked sim/os.rs algebra
# ----------------------------------------------------------------------


def _stream_rows(rows_i64: np.ndarray, mask: np.uint64):
    """(toggles, nonzeros) summed over contiguous word-stream rows, each
    starting and draining at zero — engine::stream_row_stats."""
    if rows_i64.shape[1] == 0:
        return 0, 0
    words = _u64(rows_i64) & mask
    prev = np.concatenate(
        [np.zeros((words.shape[0], 1), dtype=np.uint64), words[:, :-1]], axis=1
    )
    tog = int(_pc64(prev ^ words).sum()) + int(_pc64(words[:, -1]).sum())
    nz = int((words != 0).sum())
    return tog, nz


def simulate_os_numpy(R, C, bh, bv, A, W):
    """Vectorized port of the blocked OS engine (sim/os.rs): memoized
    activation/weight streams, closed-form drain accounting."""
    m, k = A.shape
    n = W.shape[1]
    pc = os_pass_cycles(R, k)
    mask_h = np.uint64((1 << bh) - 1)
    mask_v = np.uint64((1 << bv) - 1)
    A64 = A.astype(np.int64)
    W64 = W.astype(np.int64)
    y = A64 @ W64
    m_blocks = blocks(m, R)
    n_blocks = blocks(n, C)
    h = [0, 0, 0]
    wl = [0, 0, 0]
    v = [0, 0, 0]

    # Horizontal: memoized per m-block, scaled by the n-block replays.
    for (m0, m_len) in m_blocks:
        tog, nz = _stream_rows(A64[m0 : m0 + m_len], mask_h)
        reps = C * len(n_blocks)
        h[0] += tog * reps
        h[1] += (R * pc - nz) * reps
        h[2] += pc * R * reps

    # Weight stream: memoized per n-block, scaled by the m-block replays.
    for (n0, n_len) in n_blocks:
        tog, nz = _stream_rows(W64[:, n0 : n0 + n_len].T.copy(), mask_h)
        reps = R * len(m_blocks)
        wl[0] += tog * reps
        wl[1] += (C * pc - nz) * reps
        wl[2] += pc * C * reps

    # Drain: closed form per (m-block, n-block) pass and column.
    for (m0, m_len) in m_blocks:
        for (n0, n_len) in n_blocks:
            V = _u64(y[m0 : m0 + m_len, n0 : n0 + n_len]) & mask_v  # (m_len, n_len)
            pop = _pc64(V)
            pop_sum = pop.sum(axis=0)
            v0_pop = pop[0]
            if m_len > 1:
                d = _pc64(V[1:] ^ V[:-1])  # (m_len-1, n_len), transition j>=1
                w_tog = np.arange(m_len - 1, 0, -1, dtype=np.int64)[:, None]
                weighted_tog = (d * w_tog).sum(axis=0)
            else:
                weighted_tog = np.zeros(n_len, dtype=np.int64)
            w_nz = np.arange(m_len, 0, -1, dtype=np.int64)[:, None]
            weighted_nz = ((V != 0).astype(np.int64) * w_nz).sum(axis=0)
            v[0] += int((pop_sum + m_len * v0_pop + weighted_tog).sum())
            v[1] += R * pc * n_len - int(weighted_nz.sum())
            v[2] += pc * R * n_len
            if n_len < C:
                v[1] += (C - n_len) * pc * R
                v[2] += (C - n_len) * pc * R

    stats = {"h": h, "v": v, "wl": wl}
    return y, stats, len(m_blocks) * len(n_blocks) * pc, m * k * n


# ----------------------------------------------------------------------
# IS engine: scalar transliteration of baseline.rs::simulate_gemm_is_scalar
# ----------------------------------------------------------------------


def simulate_is_scalar(R, C, bh, bv, A, W):
    """Line-by-line port of simulate_gemm_is_scalar. Slow — used only to
    validate the vectorized IS engine on small shapes."""
    m, k = A.shape
    n = W.shape[1]
    pc = is_pass_cycles(R, C, n)
    y = A.astype(np.int64) @ W.astype(np.int64)
    stats = {key: [0, 0, 0] for key in ("h", "v", "wl")}
    cycles = macs = 0
    Al = A.tolist()
    Wl = W.tolist()
    k0 = 0
    while k0 < k:
        k_len = min(R, k - k0)
        m0 = 0
        while m0 < m:
            m_len = min(C, m - m0)
            # Activation preload chain.
            for c in range(C):
                for r in range(R):
                    tog = nz = 0
                    p = 0
                    if c < m_len:
                        for t in range(r, R):
                            rr = R - 1 - (t - r)
                            vv = Al[m0 + c][k0 + rr] if rr < k_len else 0
                            word = bus_word(vv, bh)
                            tog += bin(p ^ word).count("1")
                            nz += word != 0
                            p = word
                    stats["wl"][0] += tog
                    stats["wl"][1] += R - nz
                    stats["wl"][2] += R
            # Weight stream rows.
            for r in range(R):
                tog = nz = 0
                if r < k_len:
                    p = 0
                    for j in range(n):
                        word = bus_word(Wl[k0 + r][j], bh)
                        tog += bin(p ^ word).count("1")
                        nz += word != 0
                        p = word
                    tog += bin(p).count("1")
                stats["h"][0] += tog * C
                stats["h"][1] += (pc - nz) * C
                stats["h"][2] += pc * C
            # Vertical psums.
            for c in range(C):
                toggles = [0] * R
                nonzeros = [0] * R
                prev_words = [0] * R
                if c < m_len:
                    for j in range(n):
                        prefix = 0
                        word = 0
                        for r in range(k_len):
                            prefix += Al[m0 + c][k0 + r] * Wl[k0 + r][j]
                            word = bus_word(prefix, bv)
                            toggles[r] += bin(prev_words[r] ^ word).count("1")
                            nonzeros[r] += word != 0
                            prev_words[r] = word
                        for r in range(k_len, R):
                            toggles[r] += bin(prev_words[r] ^ word).count("1")
                            nonzeros[r] += word != 0
                            prev_words[r] = word
                    for r in range(R):
                        toggles[r] += bin(prev_words[r]).count("1")
                for r in range(R):
                    stats["v"][0] += toggles[r]
                    stats["v"][1] += pc - nonzeros[r]
                    stats["v"][2] += pc
            cycles += pc
            macs += m_len * k_len * n
            m0 += C
        k0 += R
    return y, stats, cycles, macs


# ----------------------------------------------------------------------
# IS engine: vectorized port of the blocked sim/is.rs algebra
# ----------------------------------------------------------------------


def simulate_is_numpy(R, C, bh, bv, A, W):
    """Vectorized port of the blocked IS engine (sim/is.rs): closed-form
    preload chain, memoized weight streams, prefix kernel with
    pass-through tail scaling (vectorized over the full m axis — the
    per-column chains depend only on the global m index and k-block)."""
    m, k = A.shape
    n = W.shape[1]
    pc = is_pass_cycles(R, C, n)
    mask_h = np.uint64((1 << bh) - 1)
    mask_v = np.uint64((1 << bv) - 1)
    A64 = A.astype(np.int64)
    W64 = W.astype(np.int64)
    y = A64 @ W64
    k_blocks = blocks(k, R)
    m_blocks = blocks(m, C)
    h = [0, 0, 0]
    wl = [0, 0, 0]
    v = [0, 0, 0]

    # Preload chain: closed form per pass (vectorized over columns).
    # u[c, j] = block word j of column c (zero-padded past k_len);
    #   Σ_r tog_r = R·pc(u[:,R-1]) + Σ_{j≤R-2} (j+1)·pc(u[:,j+1]^u[:,j])
    #   Σ_r nz_r  = Σ_j (j+1)·(u[:,j] != 0)
    for (k0, k_len) in k_blocks:
        for (m0, m_len) in m_blocks:
            u = np.zeros((m_len, R), dtype=np.uint64)
            u[:, :k_len] = _u64(A64[m0 : m0 + m_len, k0 : k0 + k_len]) & mask_h
            tog_tot = R * _pc64(u[:, R - 1]).astype(np.int64)
            if R > 1:
                e = _pc64(u[:, 1:] ^ u[:, :-1])  # transition into u[:, j], j<=R-2
                wj = np.arange(1, R, dtype=np.int64)[None, :]
                tog_tot = tog_tot + (e * wj).sum(axis=1)
            wn = np.arange(1, R + 1, dtype=np.int64)[None, :]
            nz_tot = ((u != 0).astype(np.int64) * wn).sum(axis=1)
            wl[0] += int(tog_tot.sum())
            wl[1] += m_len * R * R - int(nz_tot.sum()) + (C - m_len) * R * R
            wl[2] += C * R * R

    # Horizontal: memoized per k-block, scaled by the m-block replays.
    for (k0, k_len) in k_blocks:
        tog, nz = _stream_rows(W64[k0 : k0 + k_len], mask_h)
        reps = C * len(m_blocks)
        h[0] += tog * reps
        h[1] += (R * pc - nz) * reps
        h[2] += pc * R * reps

    # Vertical: prefix kernel per k-block over the full m axis; tail
    # rows replay row k_len-1; idle columns accounted per m-block.
    y_check = np.zeros_like(y)
    for (k0, k_len) in k_blocks:
        prod = A64[:, k0 : k0 + k_len].T[:, :, None] * W64[k0 : k0 + k_len, None, :]
        prefix = np.cumsum(prod, axis=0)  # (k_len, m, n)
        words = _u64(prefix) & mask_v
        prev = np.concatenate(
            [np.zeros((k_len, m, 1), dtype=np.uint64), words[:, :, :-1]], axis=2
        )
        if n > 0:
            tog = _pc64(prev ^ words).sum(axis=2) + _pc64(words[:, :, -1])
        else:
            tog = np.zeros((k_len, m), dtype=np.int64)
        nz = (words != 0).sum(axis=2).astype(np.int64)
        tail = R - k_len
        v[0] += int(tog.sum()) + tail * int(tog[-1].sum())
        v[1] += int((pc - nz).sum()) + tail * int((pc - nz[-1]).sum())
        v[2] += pc * R * m
        y_check += prefix[-1]
    for (_, m_len) in m_blocks:
        if m_len < C:
            v[1] += (C - m_len) * pc * R * len(k_blocks)
            v[2] += (C - m_len) * pc * R * len(k_blocks)
    assert np.array_equal(y_check, y), "IS prefix outputs must equal A @ W"

    stats = {"h": h, "v": v, "wl": wl}
    return y, stats, len(k_blocks) * len(m_blocks) * pc, m * k * n


# ----------------------------------------------------------------------
# serve::cache::digest_i64 (FNV-1a, length-prefixed, LE)
# ----------------------------------------------------------------------

FNV_PRIME = 0x0000_0100_0000_01B3


def _fnv1a(h: int, data: bytes) -> int:
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & MASK64
    return h


def digest_i64(seed: int, values: np.ndarray) -> int:
    h = _fnv1a(seed, len(values).to_bytes(8, "little"))
    return _fnv1a(h, values.astype("<i8").tobytes())


# ----------------------------------------------------------------------
# power::evaluate — interconnect + compute terms, f64 op-for-op
# ----------------------------------------------------------------------

# TechParams::default()
VDD = 0.9
WIRE_CAP = 0.20
CTRL_EFF_WIRES = 2.514
MAC_ENERGY_FJ = 130.0
ZERO_GATING = 0.8
FF_ENERGY_FJ_PER_BIT = 0.7
LEAKAGE_UW_PER_PE = 20.0
# PeMicroArch::default().cost(paper_32x32): the paper's constant A.
NAND2_UM2 = 0.49
UTILIZATION = 0.70


def pe_area_um2(bh: int, bv: int) -> float:
    register_bits = 2 * bh + bv
    mult_gates = 1.1 * float(bh) * float(bh)
    add_gates = 6.0 * float(bv)
    ff_gates = 4.0 * float(register_bits)
    gates = mult_gates + add_gates + ff_gates
    return gates * NAND2_UM2 / UTILIZATION


def interconnect_mw(stats, cycles, R, C, area, aspect, clock_ghz=1.0):
    w_um = math.sqrt(area * aspect)
    h_um = math.sqrt(area / aspect)
    e_wire = 0.5 * WIRE_CAP * VDD * VDD
    seconds = float(cycles) / (clock_ghz * 1e9)
    h_bus_fj = float(stats["h"][0]) * w_um * e_wire
    v_bus_fj = float(stats["v"][0]) * h_um * e_wire
    w_load_fj = float(stats["wl"][0]) * h_um * e_wire
    ctrl_fj = float(cycles) * float(R * C) * CTRL_EFF_WIRES * (w_um + h_um) * e_wire

    def to_mw(fj: float) -> float:
        return fj * 1e-15 / seconds * 1e3

    return to_mw(h_bus_fj) + to_mw(v_bus_fj) + to_mw(w_load_fj) + to_mw(ctrl_fj)


def compute_mw(stats, cycles, macs, R, C, bh, bv, clock_ghz=1.0):
    """power::evaluate's PE-internal terms (mac + reg + leak), replicated
    operation-for-operation: floorplan-invariant, so one value covers both
    geometries (the Rust generator asserts the same invariance)."""
    seconds = float(cycles) / (clock_ghz * 1e9)

    def to_mw(fj: float) -> float:
        return fj * 1e-15 / seconds * 1e3

    # Multiplier data gating over the horizontal zero fraction.
    zero_frac = float(stats["h"][1]) / float(stats["h"][2])
    scale = float(bh) / 16.0
    mac_eff_fj = (MAC_ENERGY_FJ * scale * scale) * (1.0 - ZERO_GATING * zero_frac)
    mac_fj = float(macs) * mac_eff_fj

    register_bits = 2 * bh + bv
    reg_fj = float(cycles) * float(R * C) * float(register_bits) * FF_ENERGY_FJ_PER_BIT

    leak_mw = LEAKAGE_UW_PER_PE * float(R * C) * 1e-3
    return to_mw(mac_fj) + to_mw(reg_fj) + leak_mw


def bus_mw(stats, cycles, R, C, area, aspect, clock_ghz=1.0):
    """Data-bus-only slice of interconnect_mw (horizontal input +
    vertical psum wires) — PowerBreakdown::bus_mw, the eq.-6 objective."""
    w_um = math.sqrt(area * aspect)
    h_um = math.sqrt(area / aspect)
    e_wire = 0.5 * WIRE_CAP * VDD * VDD
    seconds = float(cycles) / (clock_ghz * 1e9)
    h_bus_fj = float(stats["h"][0]) * w_um * e_wire
    v_bus_fj = float(stats["v"][0]) * h_um * e_wire

    def to_mw(fj: float) -> float:
        return fj * 1e-15 / seconds * 1e3

    return to_mw(h_bus_fj) + to_mw(v_bus_fj)


def profile_eval(layers, R, C, bh, bv, area, aspect):
    """explore::profile::StreamProfile::eval_aspect, ported: evaluate one
    floorplan candidate closed-form over stored per-layer
    (stats, cycles, macs) snapshots, averaging (bus, interconnect, total)
    power in layer order. This is the factored sweep path: the engines
    measure the snapshots once, every candidate after that is this
    function."""
    bus = ic = tot = 0.0
    for (stats, cycles, macs) in layers:
        b = bus_mw(stats, cycles, R, C, area, aspect)
        i = interconnect_mw(stats, cycles, R, C, area, aspect)
        bus += b
        ic += i
        tot += i + compute_mw(stats, cycles, macs, R, C, bh, bv)
    n = float(len(layers))
    return (bus / n, ic / n, tot / n)


def closed_form_cycles(df, R, C, m, k, n):
    """fleet::closed_form_cycles, ported: per-dataflow pass count x
    pass cost. The dataflow decides which GEMM dimensions tile onto the
    array and which dimension each pass streams."""
    if df == "ws":
        return math.ceil(k / R) * math.ceil(n / C) * pass_cycles(R, C, m)
    if df == "os":
        return math.ceil(m / R) * math.ceil(n / C) * os_pass_cycles(R, k)
    return math.ceil(k / R) * math.ceil(m / C) * is_pass_cycles(R, C, n)


# ----------------------------------------------------------------------
# Validation + generation
# ----------------------------------------------------------------------

TABLE1 = [
    # name, (P, CK^2, M) — workloads::gemm_shape over table1_layers()
    ("L1", (3136, 256, 64)),
    ("L2", (784, 1152, 128)),
    ("L3", (784, 128, 512)),
    ("L4", (196, 512, 256)),
    ("L5", (196, 1024, 256)),
    ("L6", (196, 2304, 256)),
]


def selfcheck():
    """Differential: scalar transliteration == vectorized engine."""
    rng = Rng(99)
    cases = [
        (4, 4, 8, 6, 4, 4),
        (4, 4, 8, 7, 10, 9),
        (8, 4, 8, 5, 8, 4),
        (5, 3, 12, 9, 11, 7),
        (4, 4, 16, 13, 33, 40),  # ragged multi-pass at full width
        (4, 4, 8, 1, 1, 1),
    ]
    for (R, C, bits, m, k, n) in cases:
        hi = (1 << (bits - 1)) - 1
        bv = 2 * bits + max(0, (R - 1).bit_length()) if R > 1 else 2 * bits
        A = np.array(
            [rng.next_u64() % (2 * hi + 1) - hi for _ in range(m * k)], dtype=np.int64
        ).reshape(m, k)
        W = np.array(
            [rng.next_u64() % (2 * hi + 1) - hi for _ in range(k * n)], dtype=np.int64
        ).reshape(k, n)
        ys, ss, cs, ms = simulate_ws_scalar(R, C, bits, bv, A, W)
        yv, sv, cv, mv = simulate_ws_numpy(R, C, bits, bv, A, W)
        assert np.array_equal(ys, yv), f"y mismatch {R}x{C} {m}x{k}x{n}"
        assert ss == sv, f"stats mismatch {R}x{C} {m}x{k}x{n}: {ss} vs {sv}"
        assert (cs, ms) == (cv, mv)
        assert np.array_equal(yv, A @ W), "outputs must equal exact matmul"
        # Observation conservation closed forms (mirrors the Rust
        # property suite).
        passes = math.ceil(k / R) * math.ceil(n / C)
        pc = pass_cycles(R, C, m)
        assert sv["h"][2] == passes * pc * R * C
        assert sv["v"][2] == passes * pc * R * C
        assert sv["wl"][2] == passes * R * R * C
        for key, bits_k in (("h", bits), ("v", bv), ("wl", bits)):
            tog, zer, obs = sv[key]
            assert 0 <= zer <= obs and 0 <= tog <= obs * bits_k
    # RNG sanity: scalar class and closed-form stream agree.
    r = Rng(12345)
    seq = [r.next_u64() for _ in range(100)]
    assert seq == [int(x) for x in rng_stream(12345, 100)]
    print("selfcheck: scalar == vectorized on all cases, invariants hold")


def selfcheck_dataflows():
    """Differential for the OS/IS engines: the scalar transliterations of
    the frozen Rust baselines vs the vectorized ports of the blocked
    engines' closed forms (memoized streams, drain/preload closed forms,
    pass-through tail scaling). Agreement here validates exactly the
    algebra sim/os.rs and sim/is.rs rely on."""
    rng = Rng(4242)
    cases = [
        (4, 4, 8, 6, 4, 4),
        (4, 4, 8, 7, 10, 9),     # ragged multi-pass
        (8, 4, 8, 5, 8, 4),      # non-square array
        (4, 8, 8, 9, 3, 11),     # wide array, K < R
        (5, 3, 12, 9, 11, 7),    # odd dims
        (4, 4, 16, 13, 33, 40),  # multi-block at full width
        (4, 4, 8, 1, 1, 1),      # degenerate GEMM
        (3, 5, 8, 2, 14, 2),     # deep reduction, narrow output
    ]
    for (R, C, bits, m, k, n) in cases:
        hi = (1 << (bits - 1)) - 1
        guard = (R - 1).bit_length() if R > 1 else 0
        bv = 2 * bits + guard
        A = np.array(
            [rng.next_u64() % (2 * hi + 1) - hi for _ in range(m * k)], dtype=np.int64
        ).reshape(m, k)
        W = np.array(
            [rng.next_u64() % (2 * hi + 1) - hi for _ in range(k * n)], dtype=np.int64
        ).reshape(k, n)
        for (name, scalar_fn, numpy_fn, pcyc, wl_obs) in (
            (
                "OS",
                simulate_os_scalar,
                simulate_os_numpy,
                os_pass_cycles(R, k),
                # OS weights stream for the whole pass on R·C segments.
                lambda passes, pcy: passes * pcy * R * C,
            ),
            (
                "IS",
                simulate_is_scalar,
                simulate_is_numpy,
                is_pass_cycles(R, C, n),
                # IS preload chain: R words per register per pass.
                lambda passes, _pcy: passes * R * R * C,
            ),
        ):
            ys, ss, cs, ms = scalar_fn(R, C, bits, bv, A, W)
            yv, sv, cv, mv = numpy_fn(R, C, bits, bv, A, W)
            ctx = f"{name} {R}x{C} {m}x{k}x{n}"
            assert np.array_equal(ys, yv), f"{ctx}: y mismatch"
            assert ss == sv, f"{ctx}: stats mismatch: {ss} vs {sv}"
            assert (cs, ms) == (cv, mv), f"{ctx}: cycles/macs mismatch"
            assert np.array_equal(yv, A @ W), f"{ctx}: outputs must equal matmul"
            # Conservation closed forms (mirror the Rust property suite).
            if name == "OS":
                passes = math.ceil(m / R) * math.ceil(n / C)
            else:
                passes = math.ceil(k / R) * math.ceil(m / C)
            assert cv == passes * pcyc, f"{ctx}: cycle closed form"
            assert sv["h"][2] == passes * pcyc * R * C, f"{ctx}: h obs"
            assert sv["v"][2] == passes * pcyc * R * C, f"{ctx}: v obs"
            assert sv["wl"][2] == wl_obs(passes, pcyc), f"{ctx}: wl obs"
            for key, bits_k in (("h", bits), ("v", bv), ("wl", bits)):
                tog, zer, obs = sv[key]
                assert 0 <= zer <= obs, f"{ctx}: {key} zeros"
                assert 0 <= tog <= obs * bits_k, f"{ctx}: {key} toggle capacity"
    print("selfcheck: OS/IS scalar == vectorized on all cases, invariants hold")


def selfcheck_profile():
    """Differential for the factored sweep evaluator (mirrors Rust's
    tests/profile_equivalence.rs): a profile snapshot — per-layer
    (stats, cycles, macs) — evaluates floorplan candidates to exactly the
    numbers the engine path produces, and the per-dataflow closed-form
    cycle model reproduces every engine's cycle count (including OS and
    IS, which the fleet's router score once priced with the WS formula)."""
    rng = Rng(777)
    R, C, bits = 4, 8, 8
    guard = (R - 1).bit_length()
    bv = 2 * bits + guard
    hi = (1 << (bits - 1)) - 1
    shapes = [(10, 12, 9), (7, 5, 13), (16, 3, 8)]
    area = pe_area_um2(bits, bv)
    for (df, fn) in (
        ("ws", simulate_ws_numpy),
        ("os", simulate_os_numpy),
        ("is", simulate_is_numpy),
    ):
        sims = []
        for (m, k, n) in shapes:
            A = np.array(
                [rng.next_u64() % (2 * hi + 1) - hi for _ in range(m * k)],
                dtype=np.int64,
            ).reshape(m, k)
            W = np.array(
                [rng.next_u64() % (2 * hi + 1) - hi for _ in range(k * n)],
                dtype=np.int64,
            ).reshape(k, n)
            _y, stats, cycles, macs = fn(R, C, bits, bv, A, W)
            ctx = f"{df} {R}x{C} {m}x{k}x{n}"
            assert cycles == closed_form_cycles(df, R, C, m, k, n), (
                f"{ctx}: cycle closed form"
            )
            sims.append((stats, cycles, macs))
        for aspect in (0.25, 1.0, 3.7812, 16.0):
            got = profile_eval(sims, R, C, bits, bv, area, aspect)
            # Engine path: evaluate every simulation directly, average in
            # layer order — the pre-factoring sweep loop.
            want = [0.0, 0.0, 0.0]
            for (stats, cycles, macs) in sims:
                i = interconnect_mw(stats, cycles, R, C, area, aspect)
                want[0] += bus_mw(stats, cycles, R, C, area, aspect)
                want[1] += i
                want[2] += i + compute_mw(stats, cycles, macs, R, C, bits, bv)
            want = tuple(x / float(len(sims)) for x in want)
            assert got == want, f"{df} aspect {aspect}: {got} vs {want}"
    print("selfcheck: profile-factored eval == engine path, cycle closed forms hold")


def compute_doc() -> dict:
    R, C, BH, BV = 32, 32, 16, 37
    area = pe_area_um2(BH, BV)
    layers = []
    for idx, (name, (m, k, n)) in enumerate(TABLE1):
        A = golden_matrix(m, k, INPUT_SEED + 1000 + idx, A_SPARSITY_PCT)
        W = golden_matrix(k, n, INPUT_SEED + 2000 + idx, 0)
        y, stats, cycles, macs = simulate_ws_numpy(R, C, BH, BV, A, W)
        assert np.array_equal(y, A.astype(np.int64) @ W.astype(np.int64))
        passes = math.ceil(k / R) * math.ceil(n / C)
        pc = pass_cycles(R, C, m)
        assert cycles == passes * pc and macs == m * k * n
        assert stats["h"][2] == passes * pc * R * C
        assert stats["v"][2] == passes * pc * R * C
        assert stats["wl"][2] == passes * R * R * C
        a_act = stats["h"][0] / (stats["h"][2] * BH)
        v_act = stats["v"][0] / (stats["v"][2] * BV)
        assert 0.0 < a_act <= 1.0 and 0.0 < v_act <= 1.0
        ic_sym = interconnect_mw(stats, cycles, R, C, area, 1.0)
        ic_asym = interconnect_mw(stats, cycles, R, C, area, 3.8)
        comp = compute_mw(stats, cycles, macs, R, C, BH, BV)
        entry = {
            "name": name,
            "gemm": [m, k, n],
            "horizontal": dict(
                zip(("toggles", "zero_words", "observations"), stats["h"])
            ),
            "vertical": dict(zip(("toggles", "zero_words", "observations"), stats["v"])),
            "weight_load": dict(
                zip(("toggles", "zero_words", "observations"), stats["wl"])
            ),
            "cycles": cycles,
            "macs": macs,
            "y_digest": format(digest_i64(0, y.reshape(-1)), "016x"),
            "interconnect_sym_mw": ic_sym,
            "interconnect_asym_mw": ic_asym,
            "compute_mw": comp,
            "total_sym_mw": ic_sym + comp,
            "total_asym_mw": ic_asym + comp,
        }
        layers.append(entry)
        print(
            f"{name}: {m}x{k}x{n}  a_h={a_act:.3f} a_v={v_act:.3f} "
            f"cycles={cycles} icn_sym={entry['interconnect_sym_mw']:.3f}mW "
            f"total_sym={entry['total_sym_mw']:.3f}mW"
        )
    return {
        "description": (
            "Golden bus statistics for the Table-I layers on the paper's 32x32 "
            "WS array. Regenerate with UPDATE_GOLDEN=1 cargo test --test "
            "golden_vectors."
        ),
        "sa": {"rows": R, "cols": C, "input_bits": BH, "acc_bits": BV},
        "input_seed": INPUT_SEED,
        "a_sparsity_pct": A_SPARSITY_PCT,
        "layers": layers,
    }


def compare_against(path: Path, doc: dict) -> None:
    """Value-wise comparison with the checked-in fixture: integers exact,
    floats to 1e-9 relative (the same contract golden_vectors.rs
    enforces). Exits nonzero on any disagreement, so `--check-only`
    really does arbitrate between the Rust UPDATE_GOLDEN=1 writer and
    this independent port."""
    golden = json.loads(path.read_text())
    diffs = []

    def walk(prefix, want, have):
        if isinstance(want, dict) and isinstance(have, dict):
            for key in sorted(set(want) | set(have)):
                if key not in want or key not in have:
                    diffs.append(f"{prefix}.{key}: present on one side only")
                else:
                    walk(f"{prefix}.{key}", want[key], have[key])
        elif isinstance(want, list) and isinstance(have, list):
            if len(want) != len(have):
                diffs.append(f"{prefix}: length {len(want)} vs {len(have)}")
            for i, (w, h) in enumerate(zip(want, have)):
                walk(f"{prefix}[{i}]", w, h)
        elif isinstance(want, float) or isinstance(have, float):
            if abs(want - have) > 1e-9 * max(abs(want), 1e-300):
                diffs.append(f"{prefix}: {want} vs {have}")
        elif want != have:
            diffs.append(f"{prefix}: {want!r} vs {have!r}")

    walk("fixture", golden, doc)
    if diffs:
        print(f"FIXTURE DISAGREEMENT ({len(diffs)} fields):")
        for d in diffs[:40]:
            print(" ", d)
        sys.exit(1)
    print(f"{path}: checked-in fixture matches this generator value-for-value")


def compute_dataflows_doc() -> dict:
    """OS/IS golden statistics for the same Table-I layers and golden
    operand scheme as table1.json, generated by the vectorized ports
    (differentially validated by selfcheck_dataflows). Pure integers —
    the OS/IS power paths are already covered by the sweep tier."""
    R, C, BH, BV = 32, 32, 16, 37
    layers = []
    for idx, (name, (m, k, n)) in enumerate(TABLE1):
        A = golden_matrix(m, k, INPUT_SEED + 1000 + idx, A_SPARSITY_PCT)
        W = golden_matrix(k, n, INPUT_SEED + 2000 + idx, 0)
        entry = {"name": name, "gemm": [m, k, n]}
        for key, fn, passes, pcyc in (
            (
                "os",
                simulate_os_numpy,
                math.ceil(m / R) * math.ceil(n / C),
                os_pass_cycles(R, k),
            ),
            (
                "is",
                simulate_is_numpy,
                math.ceil(k / R) * math.ceil(m / C),
                is_pass_cycles(R, C, n),
            ),
        ):
            y, stats, cycles, macs = fn(R, C, BH, BV, A, W)
            assert np.array_equal(y, A.astype(np.int64) @ W.astype(np.int64))
            assert cycles == passes * pcyc and macs == m * k * n
            assert stats["h"][2] == passes * pcyc * R * C
            assert stats["v"][2] == passes * pcyc * R * C
            entry[key] = {
                "horizontal": dict(
                    zip(("toggles", "zero_words", "observations"), stats["h"])
                ),
                "vertical": dict(
                    zip(("toggles", "zero_words", "observations"), stats["v"])
                ),
                "weight_load": dict(
                    zip(("toggles", "zero_words", "observations"), stats["wl"])
                ),
                "cycles": cycles,
                "macs": macs,
                "y_digest": format(digest_i64(0, y.reshape(-1)), "016x"),
            }
            a_act = stats["h"][0] / (stats["h"][2] * BH)
            v_act = stats["v"][0] / (stats["v"][2] * BV)
            print(
                f"{name}/{key}: {m}x{k}x{n}  a_h={a_act:.3f} a_v={v_act:.3f} "
                f"cycles={cycles}"
            )
        # Cross-engine invariant: OS and IS see the same exact product.
        assert entry["os"]["y_digest"] == entry["is"]["y_digest"]
        layers.append(entry)
    return {
        "description": (
            "Golden OS/IS bus statistics for the Table-I layers on the paper's "
            "32x32 array (same golden operand scheme as table1.json). Regenerate "
            "with UPDATE_GOLDEN=1 cargo test --test golden_dataflows."
        ),
        "sa": {"rows": R, "cols": C, "input_bits": BH, "acc_bits": BV},
        "input_seed": INPUT_SEED,
        "a_sparsity_pct": A_SPARSITY_PCT,
        "layers": layers,
    }


if __name__ == "__main__":
    selfcheck()
    selfcheck_dataflows()
    selfcheck_profile()
    golden_dir = Path(__file__).resolve().parent.parent / "rust/tests/golden"
    fixture = golden_dir / "table1.json"
    doc = compute_doc()
    df_fixture = golden_dir / "dataflows.json"
    df_doc = compute_dataflows_doc()
    if "--check-only" in sys.argv:
        compare_against(fixture, doc)
        compare_against(df_fixture, df_doc)
    else:
        golden_dir.mkdir(parents=True, exist_ok=True)
        fixture.write_text(json.dumps(doc, sort_keys=True, separators=(",", ":")))
        print(f"wrote {fixture}")
        df_fixture.write_text(json.dumps(df_doc, sort_keys=True, separators=(",", ":")))
        print(f"wrote {df_fixture}")
