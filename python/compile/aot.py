"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once at build time (`make artifacts`); Rust loads the text via
`HloModuleProto::from_text_file` and executes through PJRT.  HLO text (not
`.serialize()`) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (+ manifest.json describing shapes for the Rust side):

  layer_<name>.hlo.txt   one per Table-I layer:
                         (x f32[1,C,Hin,Win], w f32[M,CK2])
                         -> (out f32[1,M,H,W], patches_q i32[P,CK2])
  activity_block.hlo.txt (stream i32[T,L], prev i32[1,L], mask i32[1,L])
                         -> (toggles i32[1,L], zeros i32[1,L])
  tile_matmul.hlo.txt    (a f32[32,32], w f32[32,32]) -> (f32[32,32],)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

#: Fixed chunk shape of the activity oracle artifact.  Streams of any
#: length are processed in (ACTIVITY_CYCLES x ACTIVITY_LANES) chunks with
#: the `prev` row carrying state across chunk seams (exact, no seam error).
ACTIVITY_CYCLES = 4096
ACTIVITY_LANES = 64

SA_TILE = 32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_layer(layer: model.ConvLayer, tile: int = SA_TILE) -> str:
    hin, win = layer.input_hw
    x = _spec((1, layer.c, hin, win))
    w = _spec((layer.m, layer.c * layer.k * layer.k))
    fn = model.make_layer_fn(layer, tile=tile)
    return to_hlo_text(jax.jit(fn).lower(x, w))


def lower_activity() -> str:
    s = _spec((ACTIVITY_CYCLES, ACTIVITY_LANES), jnp.int32)
    p = _spec((1, ACTIVITY_LANES), jnp.int32)
    fn = model.make_activity_fn(ACTIVITY_CYCLES, ACTIVITY_LANES)
    return to_hlo_text(jax.jit(fn).lower(s, p, p))


def lower_tile_matmul(tile: int = SA_TILE) -> str:
    a = _spec((tile, tile))
    fn = model.make_tile_matmul_fn(tile)
    return to_hlo_text(jax.jit(fn).lower(a, a))


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "sa_tile": SA_TILE,
        "activity": {
            "file": "activity_block.hlo.txt",
            "cycles": ACTIVITY_CYCLES,
            "lanes": ACTIVITY_LANES,
        },
        "tile_matmul": {"file": "tile_matmul.hlo.txt", "tile": SA_TILE},
        "layers": [],
    }

    for layer in model.TABLE1_LAYERS:
        fname = f"layer_{layer.name}.hlo.txt"
        text = lower_layer(layer)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        hin, win = layer.input_hw
        p, ck2, m = layer.gemm_shape
        manifest["layers"].append(
            {
                "name": layer.name,
                "file": fname,
                "k": layer.k,
                "h": layer.h,
                "w": layer.w,
                "c": layer.c,
                "m": layer.m,
                "stride": layer.stride,
                "pad": layer.pad,
                "input_shape": [1, layer.c, hin, win],
                "weight_shape": [layer.m, ck2],
                "gemm": [p, ck2, m],
                "macs": layer.macs,
            }
        )
        print(f"  {fname}: {len(text)} chars, gemm {p}x{ck2}x{m}")

    text = lower_activity()
    with open(os.path.join(out_dir, "activity_block.hlo.txt"), "w") as f:
        f.write(text)
    print(f"  activity_block.hlo.txt: {len(text)} chars")

    text = lower_tile_matmul()
    with open(os.path.join(out_dir, "tile_matmul.hlo.txt"), "w") as f:
        f.write(text)
    print(f"  tile_matmul.hlo.txt: {len(text)} chars")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  manifest.json: {len(manifest['layers'])} layers")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    args = parser.parse_args()
    build_all(args.out)


if __name__ == "__main__":
    main()
