"""L2 JAX model: quantized conv-as-GEMM forward, as a WS systolic array runs it.

The paper evaluates 32x32 weight-stationary SAs on six ResNet50 conv
layers (Table I).  Each conv is lowered to the GEMM the SA executes:

    im2col(x): (P, CK^2) patches, P = H_out * W_out
    w:         (CK^2, M)
    y = relu(patches @ w): (P, M)

The GEMM itself is the L1 Pallas kernel (kernels.systolic_gemm.matmul_ws),
tiled 32x32 exactly like the paper's array, so the lowered HLO contains
the same compute schedule the Rust coordinator's cycle simulator models.

Everything here runs at BUILD time only: `aot.py` lowers one fixed-shape
`layer_forward` per Table-I layer (plus the activity oracle) to HLO text;
the Rust runtime loads and executes the artifacts via PJRT.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import quantize as quantize_kernels
from .kernels import systolic_gemm
from .kernels import activity as activity_kernels


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One conv layer in the paper's Table-I parameterization.

    K: kernel size, h/w: OUTPUT height/width, c: input channels,
    m: output channels.  All six selected layers are stride-1,
    'same'-padded (pad = K // 2), which is consistent with their
    positions inside ResNet50 bottleneck blocks.
    """

    name: str
    k: int
    h: int
    w: int
    c: int
    m: int
    stride: int = 1

    @property
    def pad(self) -> int:
        return self.k // 2

    @property
    def input_hw(self) -> tuple[int, int]:
        # stride-1 'same' conv: input spatial size == output spatial size.
        return (self.h * self.stride, self.w * self.stride)

    @property
    def gemm_shape(self) -> tuple[int, int, int]:
        """(M_g, K_g, N_g) of the im2col GEMM: P x CK^2 x M."""
        return (self.h * self.w, self.c * self.k * self.k, self.m)

    @property
    def macs(self) -> int:
        p, ck2, m = self.gemm_shape
        return p * ck2 * m


#: Table I of the paper: the six selected ResNet50 conv layers.
TABLE1_LAYERS: tuple[ConvLayer, ...] = (
    ConvLayer("L1", k=1, h=56, w=56, c=256, m=64),
    ConvLayer("L2", k=3, h=28, w=28, c=128, m=128),
    ConvLayer("L3", k=1, h=28, w=28, c=128, m=512),
    ConvLayer("L4", k=1, h=14, w=14, c=512, m=256),
    ConvLayer("L5", k=1, h=14, w=14, c=1024, m=256),
    ConvLayer("L6", k=3, h=14, w=14, c=256, m=256),
)


def im2col(x: jax.Array, k: int, stride: int, pad: int) -> jax.Array:
    """Extract conv patches: (1, C, H, W) -> (H_out*W_out, C*k*k).

    Column order is (c, ki, kj) row-major, matching OIHW weight reshape
    w.reshape(M, C*k*k).T — so patches @ w_mat == conv(x, w).
    """
    n, c, h, w = x.shape
    if n != 1:
        raise ValueError("single-batch inference only (paper SSIV)")
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    h_out = (h + 2 * pad - k) // stride + 1
    w_out = (w + 2 * pad - k) // stride + 1
    # Gather k*k shifted views; static python loop => unrolled, fuses well.
    cols = []
    for ki in range(k):
        for kj in range(k):
            view = jax.lax.dynamic_slice(
                xp, (0, 0, ki, kj), (1, c, (h_out - 1) * stride + 1, (w_out - 1) * stride + 1)
            )
            view = view[:, :, ::stride, ::stride]  # (1, C, H_out, W_out)
            cols.append(view.reshape(c, h_out * w_out))
    # cols[ki*k+kj][c_] -> want order (c_, ki, kj)
    stacked = jnp.stack(cols, axis=1)  # (C, k*k, P)
    return stacked.reshape(c * k * k, h_out * w_out).T  # (P, C*k*k)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def _ceil_to(v: int, q: int) -> int:
    return (v + q - 1) // q * q


def gemm_tiled(a: jax.Array, w: jax.Array, tile: int = 32) -> jax.Array:
    """Pad-to-tile + WS Pallas GEMM + slice back: the SA execution of a@w."""
    m, k = a.shape
    _, n = w.shape
    mp, kp, np_ = _ceil_to(m, tile), _ceil_to(k, tile), _ceil_to(n, tile)
    out = systolic_gemm.matmul_ws(
        _pad_to(a, mp, kp),
        _pad_to(w, kp, np_),
        block_m=tile,
        block_n=tile,
        block_k=tile,
    )
    return out[:m, :n]


def layer_forward(
    x: jax.Array, w: jax.Array, layer: ConvLayer, tile: int = 32
) -> jax.Array:
    """Quantizable conv forward: im2col -> WS GEMM -> ReLU.

    Args:
      x: (1, C, H_in, W_in) f32 input activations.
      w: (M, C*k*k) f32 weight matrix (OIHW flattened).

    Returns:
      (1, M, H_out, W_out) f32 post-ReLU output.
    """
    patches = im2col(x, layer.k, layer.stride, layer.pad)  # (P, CK^2)
    y = gemm_tiled(patches, w.T, tile=tile)  # (P, M)
    y = jnp.maximum(y, 0.0)
    return y.T.reshape(1, layer.m, layer.h, layer.w)


def quantize_sym(x: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor quantization to `bits`-bit signed integers.

    Returns (q, scale) with q int32 in [-(2^(b-1)-1), 2^(b-1)-1] and
    x ~= q * scale.  Matches quant::quantize_sym on the Rust side.
    """
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = absmax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale


def make_layer_fn(layer: ConvLayer, tile: int = 32):
    """Fixed-shape jittable forward for one Table-I layer.

    Signature: (x: (1,C,Hin,Win) f32, w: (M, C*k*k) f32)
            -> ((1,M,H,W) f32 out, (P, CK^2) i32 quantized patches).

    The quantized patches are returned alongside the activations because
    the Rust side feeds exactly these int16-range words onto the
    simulated horizontal buses (paper SSIV: 16-bit quantized inputs).
    """

    def fn(x, w):
        out = layer_forward(x, w, layer, tile=tile)
        patches = im2col(x, layer.k, layer.stride, layer.pad)
        # Quantization through the L1 Pallas kernel so it lowers into the
        # artifact alongside the GEMM (semantics == quantize_sym).
        q, _scale = quantize_kernels.quantize_sym_pallas(patches, bits=16)
        return out, q

    return fn


def make_activity_fn(cycles: int, lanes: int):
    """Fixed-shape activity oracle entry point (see kernels.activity)."""

    def fn(stream, prev, mask):
        return activity_kernels.bus_activity(stream, prev, mask)

    return fn


def make_tile_matmul_fn(tile: int = 32):
    """Quickstart artifact: one SA-sized f32 tile product."""

    def fn(a, w):
        return systolic_gemm.matmul_ws(a, w, block_m=tile, block_n=tile, block_k=tile)

    return fn
