"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest asserts the Pallas kernels
(and, via exported fixtures, the Rust implementations) match these
references bit-exactly (integers) or to f32 tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, w: jax.Array) -> jax.Array:
    """a @ w with f32/int32 accumulation — the GEMM oracle."""
    acc = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    return jnp.matmul(a, w, preferred_element_type=acc)


def toggles_ref(
    stream: jax.Array, prev: jax.Array, mask: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Per-lane toggle and zero counts — the switching-activity oracle.

    Same contract as kernels.activity.bus_activity.
    """
    xm = jnp.bitwise_and(stream.astype(jnp.int32), mask)
    prevm = jnp.bitwise_and(prev.astype(jnp.int32), mask)
    shifted = jnp.concatenate([prevm, xm[:-1, :]], axis=0)
    flips = jax.lax.population_count(jnp.bitwise_xor(xm, shifted))
    toggles = jnp.sum(flips, axis=0, keepdims=True)
    zeros = jnp.sum((xm == 0).astype(jnp.int32), axis=0, keepdims=True)
    return toggles, zeros


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int, pad: int) -> jax.Array:
    """NCHW conv oracle via lax.conv for validating im2col+GEMM forward."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
