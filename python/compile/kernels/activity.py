"""L1 Pallas kernel: bus switching-activity (bit-toggle) counting.

The paper's eq. (6) scales bus widths by the *average switching activity*
(a_h, a_v) measured on the horizontal input buses and vertical partial-sum
buses of the SA.  This kernel is the vectorized oracle for that
measurement: given a (T, L) matrix of int32 bus words -- L parallel bus
instances observed for T consecutive cycles -- it counts, per lane,

  * toggles: sum_t popcount((x[t] ^ x[t-1]) & mask)
  * zeros:   number of cycles the masked word is exactly 0

`mask` keeps only the physical wires of the bus (B_h=16 or B_v=37-wide
buses are carried in one/two int32 words; see `pack_words`).  The first
row is diffed against a caller-provided `prev` row so that long streams
can be processed in fixed-shape chunks with exact results (chunk seams
carry no error) -- this is how the Rust runtime calls the AOT artifact.

The same counting is implemented in Rust (`activity::oracle`) and both are
checked against `kernels.ref.toggles_ref` in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _activity_kernel(stream_ref, prev_ref, mask_ref, tog_ref, zer_ref):
    x = stream_ref[...]  # (T, L) int32
    prev = prev_ref[...]  # (1, L) int32
    mask = mask_ref[...]  # (1, L) int32
    xm = jnp.bitwise_and(x, mask)
    prevm = jnp.bitwise_and(prev, mask)
    shifted = jnp.concatenate([prevm, xm[:-1, :]], axis=0)
    flips = jax.lax.population_count(jnp.bitwise_xor(xm, shifted))
    tog_ref[...] = jnp.sum(flips, axis=0, keepdims=True)
    zer_ref[...] = jnp.sum((xm == 0).astype(jnp.int32), axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=())
def bus_activity(
    stream: jax.Array, prev: jax.Array, mask: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Count per-lane bit toggles and zero-valued cycles.

    Args:
      stream: (T, L) int32 bus words, row t = cycle t.
      prev:   (1, L) int32 word on each lane in the cycle before row 0
              (use zeros for the true start of a stream -- buses reset low).
      mask:   (1, L) int32 bit-mask of physically present wires per lane.

    Returns:
      (toggles, zeros): each (1, L) int32.
    """
    t, l = stream.shape
    if prev.shape != (1, l) or mask.shape != (1, l):
        raise ValueError(
            f"prev/mask must be (1,{l}); got {prev.shape}, {mask.shape}"
        )
    return pl.pallas_call(
        _activity_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1, l), jnp.int32),
            jax.ShapeDtypeStruct((1, l), jnp.int32),
        ),
        interpret=True,
    )(stream, prev, mask)


def pack_words(values: jax.Array, bits: int) -> jax.Array:
    """Mask signed values to a `bits`-wide bus word (two's complement).

    A B-bit bus carries value & (2**B - 1); for B <= 32 one int32 word per
    bus instance suffices for toggle counting (the paper's widest bus is
    B_v=37; the Rust simulator splits those into lo/hi words -- see
    activity::oracle -- while this JAX path handles the <=32-bit lanes).
    """
    if not 1 <= bits <= 32:
        raise ValueError(f"bits must be in [1,32], got {bits}")
    mask = jnp.int32((1 << bits) - 1) if bits < 32 else jnp.int32(-1)
    return jnp.bitwise_and(values.astype(jnp.int32), mask)
