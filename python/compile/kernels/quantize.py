"""L1 Pallas kernel: symmetric int16 quantization of the bus operands.

The paper's arrays consume *16-bit integer quantized* inputs and weights
(SSIV).  Quantization therefore sits on the artifact's data path right
before the horizontal buses: this kernel maps f32 activations to int
words given a precomputed scale.  It runs blocked over rows so arbitrary
(P, CK^2) patch matrices stream through a fixed VMEM working set, and it
lowers into the same HLO module as the GEMM kernel (interpret=True; see
systolic_gemm.py for the TPU adaptation notes).

The absmax -> scale reduction is a two-pass affair (scale needs a global
max); the host/jnp side computes the scalar, the kernel does the heavy
per-element map.  Matches quant::quantize_sym on the Rust side and
model.quantize_sym's semantics exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, scale_ref, qmax_ref, o_ref):
    x = x_ref[...]
    scale = scale_ref[0, 0]
    qmax = qmax_ref[0, 0]
    # Divide (not multiply-by-reciprocal): bit-identical to the jnp
    # reference and the Rust quantizer at the round-half boundaries.
    q = jnp.round(x / scale)
    o_ref[...] = jnp.clip(q, -qmax, qmax).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "block_rows"))
def quantize_sym_pallas(
    x: jax.Array, bits: int = 16, block_rows: int = 128
) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor quantization via a blocked Pallas kernel.

    Args:
      x: (R, C) f32 tensor.
      bits: target signed width (values in [-(2^(b-1)-1), 2^(b-1)-1]).
      block_rows: rows per grid step (VMEM working set control).

    Returns:
      (q, scale): q int32 of x.shape with x ~= q * scale.
    """
    if x.ndim != 2:
        raise ValueError(f"expected 2-D input, got shape {x.shape}")
    if not 2 <= bits <= 16:
        raise ValueError(f"bits must be in [2,16], got {bits}")
    rows, cols = x.shape
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = absmax / qmax
    scale_arr = scale.reshape(1, 1).astype(jnp.float32)
    qmax_arr = jnp.full((1, 1), qmax, dtype=jnp.float32)

    # Pad rows to the block size; slice back after.
    padded = (rows + block_rows - 1) // block_rows * block_rows
    xp = jnp.pad(x, ((0, padded - rows), (0, 0)))
    grid = (padded // block_rows,)
    q = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, cols), jnp.int32),
        interpret=True,
    )(xp, scale_arr, qmax_arr)
    return q[:rows, :], scale
