"""L1 Pallas kernel: weight-stationary tiled GEMM.

This is the compute hot-spot of every conv layer in the paper (conv lowered
to GEMM via im2col, exactly as a weight-stationary systolic array executes
it).  The BlockSpec grid mirrors the WS schedule of the paper's SA:

  * grid step (i, j, k) holds ONE (block_k x block_n) weight tile resident
    ("weight stationary") while a (block_m x block_k) slab of activations
    streams against it,
  * partial sums accumulate across the k-grid dimension in the output ref,
    which is the software analogue of the vertical psum chain whose bus
    width/activity the paper optimizes the floorplan for.

TPU adaptation (DESIGN.md SS5): the paper's SA is a 28nm ASIC; on TPU the
same structure is the MXU systolic array.  Block shapes default to 32x32
(the paper's array size; also MXU-aligned multiples of 8x128 would be used
on real hardware).  VMEM footprint per grid step is
  block_m*block_k + block_k*block_n + block_m*block_n  words,
kept well under VMEM limits (see DESIGN.md SS8).

Kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls; numerics are validated against kernels.ref via pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, w_ref, o_ref):
    """One WS grid step: o += a @ w with the k==0 step initializing o.

    The k grid dimension is the reduction; `o_ref` persists across k steps
    for a fixed (i, j), so accumulation happens in the output block -- the
    software mirror of the SA's vertical partial-sum chain.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def _check_tiling(m: int, k: int, n: int, bm: int, bk: int, bn: int) -> None:
    if m % bm or k % bk or n % bn:
        raise ValueError(
            f"matmul_ws requires dims divisible by blocks: "
            f"(M,K,N)=({m},{k},{n}) blocks=({bm},{bk},{bn})"
        )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k")
)
def matmul_ws(
    a: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 32,
    block_n: int = 32,
    block_k: int = 32,
) -> jax.Array:
    """Weight-stationary tiled matmul `a @ w` as a Pallas kernel.

    Args:
      a: (M, K) activations (f32 or i32).
      w: (K, N) weights, same dtype as `a`.
      block_*: tile sizes; all dims must divide evenly (pad upstream).

    Returns:
      (M, N) product. f32 in -> f32 out; i32 in -> i32 out (caller must
      guarantee |partial sums| < 2**31; the Rust cycle simulator models the
      paper's exact 37-bit accumulator, this kernel is the bulk compute
      path).
    """
    m, k = a.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {w.shape}")
    _check_tiling(m, k, n, block_m, block_k, block_n)
    if a.dtype != w.dtype:
        raise ValueError(f"dtype mismatch: {a.dtype} vs {w.dtype}")
    acc_dtype = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            # Activation slab streams along k for a fixed row-block i.
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            # Weight tile: "stationary" w.r.t. the m-stream, advances with k/j.
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        interpret=True,
    )(a, w)


def vmem_words_per_step(block_m: int, block_k: int, block_n: int) -> int:
    """VMEM working-set estimate (in 4-byte words) for one grid step.

    Used by DESIGN.md SS8 / the perf pass to keep the schedule under the
    16 MiB VMEM budget of a real TPU core.
    """
    return block_m * block_k + block_k * block_n + block_m * block_n
