"""L1 kernel correctness: Pallas systolic GEMM vs pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and block sizes; every case asserts
allclose (f32) or bit-exact equality (int32) against kernels.ref.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, systolic_gemm

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == jnp.int32:
        return jnp.asarray(rng.integers(-100, 100, size=shape), dtype=jnp.int32)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_matmul_ws_basic(dtype):
    a = _rand((64, 96), dtype, 0)
    w = _rand((96, 32), dtype, 1)
    got = systolic_gemm.matmul_ws(a, w)
    want = ref.matmul_ref(a, w)
    if dtype == jnp.int32:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@hypothesis.given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    ni=st.integers(1, 4),
    block=st.sampled_from([8, 16, 32]),
    is_int=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_matmul_ws_shapes(mi, ki, ni, block, is_int, seed):
    m, k, n = mi * block, ki * block, ni * block
    dtype = jnp.int32 if is_int else jnp.float32
    a = _rand((m, k), dtype, seed)
    w = _rand((k, n), dtype, seed + 1)
    got = systolic_gemm.matmul_ws(a, w, block_m=block, block_n=block, block_k=block)
    want = ref.matmul_ref(a, w)
    assert got.dtype == want.dtype
    if is_int:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_ws_rejects_untiled():
    a = jnp.zeros((33, 32), jnp.float32)
    w = jnp.zeros((32, 32), jnp.float32)
    with pytest.raises(ValueError, match="divisible"):
        systolic_gemm.matmul_ws(a, w)


def test_matmul_ws_rejects_mismatched_inner():
    a = jnp.zeros((32, 64), jnp.float32)
    w = jnp.zeros((32, 32), jnp.float32)
    with pytest.raises(ValueError, match="inner dims"):
        systolic_gemm.matmul_ws(a, w)


def test_matmul_ws_rejects_mixed_dtype():
    a = jnp.zeros((32, 32), jnp.float32)
    w = jnp.zeros((32, 32), jnp.int32)
    with pytest.raises(ValueError, match="dtype"):
        systolic_gemm.matmul_ws(a, w)


def test_matmul_ws_rectangular_blocks():
    a = _rand((64, 128), jnp.float32, 7)
    w = _rand((128, 96), jnp.float32, 8)
    got = systolic_gemm.matmul_ws(a, w, block_m=16, block_n=32, block_k=64)
    np.testing.assert_allclose(got, ref.matmul_ref(a, w), rtol=1e-4, atol=1e-4)


def test_vmem_words_per_step():
    # 32x32x32 blocks: 3 * 1024 words = 12 KiB — far under 16 MiB VMEM.
    assert systolic_gemm.vmem_words_per_step(32, 32, 32) == 3 * 32 * 32
