"""Pallas quantization kernel vs the jnp reference (bit-exact)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import quantize

hypothesis.settings.register_profile(
    "quant", max_examples=8, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("quant")


@hypothesis.given(
    # Fixed shape pool: each distinct shape triggers a jit compile of the
    # interpret-mode kernel, so the pool is kept small.
    shape=st.sampled_from([(1, 1), (7, 5), (64, 32), (130, 16)]),
    bits=st.sampled_from([4, 8, 16]),
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**16),
)
def test_matches_jnp_reference(shape, bits, scale, seed):
    rows, cols = shape
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)) * scale, jnp.float32)
    q_k, s_k = quantize.quantize_sym_pallas(x, bits=bits, block_rows=64)
    q_r, s_r = model.quantize_sym(x, bits=bits)
    np.testing.assert_array_equal(q_k, q_r)
    assert float(s_k) == pytest.approx(float(s_r), rel=1e-7)


def test_range_clamped():
    x = jnp.asarray([[1e6, -1e6, 0.0, 1.0]], jnp.float32)
    q, _ = quantize.quantize_sym_pallas(x, bits=16)
    assert int(jnp.max(q)) == 2**15 - 1
    assert int(jnp.min(q)) == -(2**15 - 1)
    assert int(q[0, 2]) == 0


def test_zero_tensor():
    q, s = quantize.quantize_sym_pallas(jnp.zeros((8, 8), jnp.float32))
    np.testing.assert_array_equal(q, 0)
    assert float(s) > 0


def test_block_seams_are_invisible():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((130, 10)), jnp.float32)
    q_a, _ = quantize.quantize_sym_pallas(x, block_rows=128)
    q_b, _ = quantize.quantize_sym_pallas(x, block_rows=13)
    np.testing.assert_array_equal(q_a, q_b)


def test_rejects_bad_args():
    with pytest.raises(ValueError):
        quantize.quantize_sym_pallas(jnp.zeros((2, 2, 2), jnp.float32))
    with pytest.raises(ValueError):
        quantize.quantize_sym_pallas(jnp.zeros((2, 2), jnp.float32), bits=1)
