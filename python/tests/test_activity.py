"""Switching-activity kernel correctness: Pallas vs oracle vs hand counts."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import activity, ref

hypothesis.settings.register_profile(
    "activity", max_examples=30, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("activity")


def _mask(bits, lanes):
    m = (1 << bits) - 1 if bits < 32 else -1
    return jnp.full((1, lanes), m, dtype=jnp.int32)


def test_bus_activity_hand_example():
    # lane 0: 0 -> 1 -> 3 -> 3 : toggles = 1 + 1 + 0 = 2, zeros = 0
    # lane 1: 0 -> 0 -> 0 -> 7 : toggles = 0 + 0 + 3 = 3, zeros = 2
    stream = jnp.array([[1, 0], [3, 0], [3, 7]], dtype=jnp.int32)
    prev = jnp.zeros((1, 2), dtype=jnp.int32)
    tog, zer = activity.bus_activity(stream, prev, _mask(16, 2))
    np.testing.assert_array_equal(tog, [[2, 3]])
    np.testing.assert_array_equal(zer, [[0, 2]])


def test_bus_activity_mask_clips_wires():
    # Value 0xFFFF on a 8-bit bus: only 8 wires exist.
    stream = jnp.array([[0xFFFF]], dtype=jnp.int32)
    prev = jnp.zeros((1, 1), dtype=jnp.int32)
    tog, zer = activity.bus_activity(stream, prev, _mask(8, 1))
    np.testing.assert_array_equal(tog, [[8]])
    np.testing.assert_array_equal(zer, [[0]])


def test_bus_activity_negative_twos_complement():
    # -1 on a 16-bit bus = 0xFFFF: 16 toggles from 0, and not a zero word.
    stream = jnp.array([[-1]], dtype=jnp.int32)
    prev = jnp.zeros((1, 1), dtype=jnp.int32)
    tog, zer = activity.bus_activity(stream, prev, _mask(16, 1))
    np.testing.assert_array_equal(tog, [[16]])
    np.testing.assert_array_equal(zer, [[0]])


@hypothesis.given(
    t=st.integers(1, 64),
    lanes=st.integers(1, 8),
    bits=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_bus_activity_matches_ref(t, lanes, bits, seed):
    rng = np.random.default_rng(seed)
    stream = jnp.asarray(
        rng.integers(-(2**15), 2**15, size=(t, lanes)), dtype=jnp.int32
    )
    prev = jnp.asarray(rng.integers(-(2**15), 2**15, size=(1, lanes)), dtype=jnp.int32)
    mask = _mask(bits, lanes)
    got_t, got_z = activity.bus_activity(stream, prev, mask)
    want_t, want_z = ref.toggles_ref(stream, prev, mask)
    np.testing.assert_array_equal(got_t, want_t)
    np.testing.assert_array_equal(got_z, want_z)


@hypothesis.given(
    t=st.integers(2, 64),
    cut=st.integers(1, 63),
    seed=st.integers(0, 2**16),
)
def test_chunked_equals_whole(t, cut, seed):
    """Chunk seams carry no error: prev-row threading is exact."""
    hypothesis.assume(cut < t)
    rng = np.random.default_rng(seed)
    stream = jnp.asarray(rng.integers(0, 2**16, size=(t, 4)), dtype=jnp.int32)
    prev0 = jnp.zeros((1, 4), dtype=jnp.int32)
    mask = _mask(16, 4)

    whole_t, whole_z = activity.bus_activity(stream, prev0, mask)
    t1, z1 = activity.bus_activity(stream[:cut], prev0, mask)
    t2, z2 = activity.bus_activity(stream[cut:], stream[cut - 1 : cut], mask)
    np.testing.assert_array_equal(whole_t, t1 + t2)
    np.testing.assert_array_equal(whole_z, z1 + z2)


def test_pack_words_masks():
    v = jnp.array([-1, 0, 5], dtype=jnp.int32)
    np.testing.assert_array_equal(
        activity.pack_words(v, 16), [0xFFFF, 0, 5]
    )


def test_pack_words_rejects_bad_width():
    with pytest.raises(ValueError):
        activity.pack_words(jnp.zeros(1, jnp.int32), 0)
    with pytest.raises(ValueError):
        activity.pack_words(jnp.zeros(1, jnp.int32), 33)


def test_bus_activity_shape_validation():
    with pytest.raises(ValueError):
        activity.bus_activity(
            jnp.zeros((4, 2), jnp.int32),
            jnp.zeros((1, 3), jnp.int32),
            jnp.zeros((1, 2), jnp.int32),
        )
