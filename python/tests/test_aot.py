"""AOT lowering smoke tests: every artifact lowers to parseable HLO text."""

import json
import os

import pytest

from compile import aot, model


def test_lower_tile_matmul_is_hlo_text():
    text = aot.lower_tile_matmul()
    assert "HloModule" in text
    assert "f32[32,32]" in text


def test_lower_activity_is_hlo_text():
    text = aot.lower_activity()
    assert "HloModule" in text
    assert f"s32[{aot.ACTIVITY_CYCLES},{aot.ACTIVITY_LANES}]" in text


def test_lower_smallest_layer():
    # Lower a reduced layer (same code path as Table-I, smaller shapes) to
    # keep the test fast; full layers are lowered by `make artifacts`.
    layer = model.ConvLayer("t", k=1, h=8, w=8, c=32, m=32)
    text = aot.lower_layer(layer)
    assert "HloModule" in text


def test_build_all_manifest(tmp_path, monkeypatch):
    # Patch the layer table to one tiny layer so the test stays fast.
    tiny = (model.ConvLayer("T0", k=1, h=8, w=8, c=32, m=32),)
    monkeypatch.setattr(model, "TABLE1_LAYERS", tiny)
    manifest = aot.build_all(str(tmp_path))
    assert (tmp_path / "manifest.json").exists()
    assert (tmp_path / "layer_T0.hlo.txt").exists()
    assert (tmp_path / "activity_block.hlo.txt").exists()
    assert (tmp_path / "tile_matmul.hlo.txt").exists()
    with open(tmp_path / "manifest.json") as f:
        on_disk = json.load(f)
    assert on_disk["layers"][0]["name"] == "T0"
    assert on_disk["layers"][0]["gemm"] == [64, 32, 32]
    assert manifest["sa_tile"] == aot.SA_TILE
