"""L2 model correctness: im2col+GEMM forward vs lax.conv oracle."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

hypothesis.settings.register_profile(
    "model", max_examples=15, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("model")


def _conv_via_model(x, w_oihw, layer):
    w_mat = w_oihw.reshape(layer.m, layer.c * layer.k * layer.k)
    return model.layer_forward(x, w_mat, layer)


@pytest.mark.parametrize("k,stride", [(1, 1), (3, 1)])
def test_layer_forward_matches_lax_conv(k, stride):
    layer = model.ConvLayer("t", k=k, h=8, w=8, c=4, m=6, stride=stride)
    rng = np.random.default_rng(0)
    hin, win = layer.input_hw
    x = jnp.asarray(rng.standard_normal((1, layer.c, hin, win)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((layer.m, layer.c, k, k)), jnp.float32)
    got = _conv_via_model(x, w, layer)
    want = jnp.maximum(ref.conv2d_ref(x, w, stride, layer.pad), 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@hypothesis.given(
    k=st.sampled_from([1, 3, 5]),
    hw=st.integers(4, 12),
    c=st.integers(1, 8),
    m=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_im2col_gemm_equals_conv(k, hw, c, m, seed):
    layer = model.ConvLayer("t", k=k, h=hw, w=hw, c=c, m=m)
    rng = np.random.default_rng(seed)
    hin, win = layer.input_hw
    x = jnp.asarray(rng.standard_normal((1, c, hin, win)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((m, c, k, k)), jnp.float32)
    got = _conv_via_model(x, w, layer)
    want = jnp.maximum(ref.conv2d_ref(x, w, 1, layer.pad), 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_im2col_shape_and_order():
    layer = model.ConvLayer("t", k=3, h=5, w=5, c=2, m=1)
    x = jnp.arange(2 * 5 * 5, dtype=jnp.float32).reshape(1, 2, 5, 5)
    patches = model.im2col(x, layer.k, layer.stride, layer.pad)
    assert patches.shape == (25, 18)
    # Center patch (2,2) with pad=1: column (c=0, ki=1, kj=1) = x[0,0,2,2].
    center_idx = 2 * 5 + 2
    col_idx = 0 * 9 + 1 * 3 + 1
    assert patches[center_idx, col_idx] == x[0, 0, 2, 2]


def test_im2col_rejects_batch():
    with pytest.raises(ValueError, match="single-batch"):
        model.im2col(jnp.zeros((2, 1, 4, 4)), 1, 1, 0)


def test_quantize_sym_roundtrip():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    q, scale = model.quantize_sym(x, bits=16)
    assert q.dtype == jnp.int32
    assert int(jnp.max(jnp.abs(q))) <= 2**15 - 1
    np.testing.assert_allclose(q * scale, x, atol=float(scale) * 0.51)


def test_quantize_sym_zero_input():
    q, scale = model.quantize_sym(jnp.zeros((4, 4)), bits=16)
    np.testing.assert_array_equal(q, 0)
    assert float(scale) > 0


def test_table1_gemm_shapes():
    """Table I layers produce the GEMM dims the paper's SA executes."""
    shapes = {l.name: l.gemm_shape for l in model.TABLE1_LAYERS}
    assert shapes["L1"] == (56 * 56, 256, 64)
    assert shapes["L2"] == (28 * 28, 128 * 9, 128)
    assert shapes["L3"] == (28 * 28, 128, 512)
    assert shapes["L4"] == (14 * 14, 512, 256)
    assert shapes["L5"] == (14 * 14, 1024, 256)
    assert shapes["L6"] == (14 * 14, 256 * 9, 256)


def test_gemm_tiled_pads_and_slices():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((33, 17)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((17, 9)), jnp.float32)
    got = model.gemm_tiled(a, w)
    np.testing.assert_allclose(got, a @ w, rtol=1e-4, atol=1e-4)
